"""Gather-scatter and CG solver properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.cg import cg, ir_solve
from repro.core.geom import BoxMesh
from repro.core.gs import ds_sum_local
from repro.core.nekbone import NekboneCase


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 2 ** 16),
       grid=st.tuples(st.integers(1, 3), st.integers(1, 3), st.integers(1, 3)))
def test_ds_sum_properties(seed, grid):
    n = 4
    rng = np.random.default_rng(seed)
    mesh = BoxMesh(n, grid)
    u = jnp.asarray(rng.normal(size=(mesh.nelt, n, n, n)))
    su = ds_sum_local(u, grid)
    mult = jnp.asarray(mesh.multiplicity())
    # 1) ds output is continuous: ds(ds(u)) == mult * ds(u)
    np.testing.assert_allclose(np.asarray(ds_sum_local(su, grid)),
                               np.asarray(mult * su), rtol=1e-6, atol=1e-6)
    # 2) global sum is preserved per copy weighting: sum(ds(u)/mult) == sum(u)
    np.testing.assert_allclose(float(jnp.sum(su / mult)), float(jnp.sum(u)),
                               rtol=1e-5, atol=1e-5)
    # 3) interior nodes untouched
    interior = np.asarray(mult) == 1
    np.testing.assert_allclose(np.asarray(su)[interior],
                               np.asarray(u)[interior])


def test_multiplicity_structure():
    mesh = BoxMesh(3, (2, 2, 2))
    m = mesh.multiplicity()
    assert m.max() == 8.0, "center corner shared by 8 elements"
    assert m.min() == 1.0
    # total duplicated dofs = sum over unique nodes of multiplicity
    assert int(m.sum()) >= mesh.nunique


@pytest.mark.parametrize("precond", [None, "jacobi"])
def test_cg_manufactured_solution(precond, x64):
    case = NekboneCase(n=8, grid=(3, 3, 3), dtype=jnp.float64)
    res, u_ex = case.solve_manufactured(tol=1e-10, max_iter=400,
                                        precond=precond)
    err = float(case.solution_error(res.x, u_ex))
    assert err < 1e-8, f"spectral accuracy lost: {err}"
    assert int(res.iters) < 200
    hist = np.asarray(res.rnorm_history)
    hist = hist[np.isfinite(hist)]
    assert hist[-1] < hist[0] * 1e-6, "residual must drop"


def test_jacobi_speeds_up_cg(x64):
    case = NekboneCase(n=8, grid=(3, 3, 3), dtype=jnp.float64)
    r0, _ = case.solve_manufactured(tol=1e-9, max_iter=500, precond=None)
    r1, _ = case.solve_manufactured(tol=1e-9, max_iter=500,
                                   precond="jacobi")
    assert int(r1.iters) < int(r0.iters)


def test_cg_fixed_iters_matches_paper_protocol():
    """The paper runs exactly 100 CG iterations; check the driver does."""
    case = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float32)
    res, _ = case.solve_manufactured(niter=100)
    assert int(res.iters) == 100
    assert res.rnorm_history.shape == (101,)


def test_cg_tol_early_exit_and_history_padding(x64):
    """The while_loop path: iters < max_iter, NaN padding past the exit."""
    case = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float64)
    _, f = case.manufactured()
    max_iter = 200
    res = cg(case.ax_full, f, tol=1e-6, max_iter=max_iter, dot=case.dot())
    it = int(res.iters)
    hist = np.asarray(res.rnorm_history)
    assert 0 < it < max_iter
    assert hist.shape == (max_iter + 1,)
    assert np.isfinite(hist[:it + 1]).all()
    assert np.isnan(hist[it + 1:]).all()
    # unpreconditioned: the stopping rtz IS r·c·r, so the recorded final
    # norm satisfies the tolerance
    assert float(res.rnorm) <= 1e-6
    assert float(res.rnorm) == hist[it]


@pytest.mark.parametrize("dtype,tol,hist_rtol", [
    # the restart recomputes b - A x0, so its r0 differs from the first
    # stage's recursively-updated residual by the true-vs-recursive gap —
    # O(eps * kappa) of the working dtype
    (jnp.float32, 1e-4, 1e-3),
    (jnp.float64, 1e-9, 1e-10),
])
def test_cg_restart_from_x0(dtype, tol, hist_rtol, x64):
    """x0 != 0 restarts: a split solve continues where the first left off."""
    case = NekboneCase(n=6, grid=(2, 2, 2), dtype=dtype)
    _, f = case.manufactured()
    stage1 = cg(case.ax_full, f, tol=tol, max_iter=15, dot=case.dot())
    assert int(stage1.iters) == 15          # capped, not converged
    stage2 = cg(case.ax_full, f, x0=stage1.x, tol=tol, max_iter=400,
                dot=case.dot())
    assert float(stage2.rnorm) <= tol
    # the restart's initial residual is the first stage's final one
    h1, h2 = np.asarray(stage1.rnorm_history), np.asarray(stage2.rnorm_history)
    np.testing.assert_allclose(h2[0], h1[15], rtol=hist_rtol)
    # restarting from the converged solution exits before iterating
    stage3 = cg(case.ax_full, f, x0=stage2.x, tol=tol, max_iter=400,
                dot=case.dot())
    assert int(stage3.iters) == 0
    assert np.isnan(np.asarray(stage3.rnorm_history)[1:]).all()


def test_cg_fixed_iters_x0_restart_matches_protocol(x64):
    """cg_fixed_iters with x0: runs exactly niter more, residual drops."""
    case = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float64)
    _, f = case.manufactured()
    from repro.core.cg import cg_fixed_iters

    first = cg_fixed_iters(case.ax_full, f, niter=10, dot=case.dot())
    second = cg_fixed_iters(case.ax_full, f, niter=10, x0=first.x,
                            dot=case.dot())
    assert int(second.iters) == 10
    assert float(second.rnorm) < float(first.rnorm)
    straight = cg_fixed_iters(case.ax_full, f, niter=20, dot=case.dot())
    # a restart discards the Krylov space, so it trails the straight run —
    # but not by orders of magnitude on a well-conditioned case
    assert float(second.rnorm) < float(straight.rnorm) * 1e3


def test_mixed_precision_iterative_refinement(x64):
    """IR with an f32 inner CG reaches f64-grade residuals (DESIGN.md §5)."""
    case64 = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float64)
    case32 = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float32)
    u_ex, f = case64.manufactured()

    def inner(r32):
        # relative inner tolerance: the residual shrinks every outer pass
        tol = 1e-6 * jnp.linalg.norm(r32.ravel())
        return cg(case32.ax_full, r32, tol=tol, max_iter=300,
                  dot=case32.dot()).x

    x, norms = ir_solve(case64.ax_full, f, inner, outer_iters=4)
    rel = float(norms[-1] / norms[0])
    assert rel < 1e-8, f"IR did not refine: {rel}"
    # solution error floor = spectral discretization error at n=6, not solver
    err = float(case64.solution_error(x, u_ex))
    assert err < 1e-5
