"""Gather-scatter and CG solver properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.cg import cg, ir_solve
from repro.core.geom import BoxMesh
from repro.core.gs import ds_sum_local
from repro.core.nekbone import NekboneCase


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 2 ** 16),
       grid=st.tuples(st.integers(1, 3), st.integers(1, 3), st.integers(1, 3)))
def test_ds_sum_properties(seed, grid):
    n = 4
    rng = np.random.default_rng(seed)
    mesh = BoxMesh(n, grid)
    u = jnp.asarray(rng.normal(size=(mesh.nelt, n, n, n)))
    su = ds_sum_local(u, grid)
    mult = jnp.asarray(mesh.multiplicity())
    # 1) ds output is continuous: ds(ds(u)) == mult * ds(u)
    np.testing.assert_allclose(np.asarray(ds_sum_local(su, grid)),
                               np.asarray(mult * su), rtol=1e-6, atol=1e-6)
    # 2) global sum is preserved per copy weighting: sum(ds(u)/mult) == sum(u)
    np.testing.assert_allclose(float(jnp.sum(su / mult)), float(jnp.sum(u)),
                               rtol=1e-5, atol=1e-5)
    # 3) interior nodes untouched
    interior = np.asarray(mult) == 1
    np.testing.assert_allclose(np.asarray(su)[interior],
                               np.asarray(u)[interior])


def test_multiplicity_structure():
    mesh = BoxMesh(3, (2, 2, 2))
    m = mesh.multiplicity()
    assert m.max() == 8.0, "center corner shared by 8 elements"
    assert m.min() == 1.0
    # total duplicated dofs = sum over unique nodes of multiplicity
    assert int(m.sum()) >= mesh.nunique


@pytest.mark.parametrize("precond", [False, True])
def test_cg_manufactured_solution(precond, x64):
    case = NekboneCase(n=8, grid=(3, 3, 3), dtype=jnp.float64)
    res, u_ex = case.solve_manufactured(tol=1e-10, max_iter=400,
                                        precond=precond)
    err = float(case.solution_error(res.x, u_ex))
    assert err < 1e-8, f"spectral accuracy lost: {err}"
    assert int(res.iters) < 200
    hist = np.asarray(res.rnorm_history)
    hist = hist[np.isfinite(hist)]
    assert hist[-1] < hist[0] * 1e-6, "residual must drop"


def test_jacobi_speeds_up_cg(x64):
    case = NekboneCase(n=8, grid=(3, 3, 3), dtype=jnp.float64)
    r0, _ = case.solve_manufactured(tol=1e-9, max_iter=500, precond=False)
    r1, _ = case.solve_manufactured(tol=1e-9, max_iter=500, precond=True)
    assert int(r1.iters) < int(r0.iters)


def test_cg_fixed_iters_matches_paper_protocol():
    """The paper runs exactly 100 CG iterations; check the driver does."""
    case = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float32)
    res, _ = case.solve_manufactured(niter=100)
    assert int(res.iters) == 100
    assert res.rnorm_history.shape == (101,)


def test_mixed_precision_iterative_refinement(x64):
    """IR with an f32 inner CG reaches f64-grade residuals (DESIGN.md §5)."""
    case64 = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float64)
    case32 = NekboneCase(n=6, grid=(2, 2, 2), dtype=jnp.float32)
    u_ex, f = case64.manufactured()

    def inner(r32):
        # relative inner tolerance: the residual shrinks every outer pass
        tol = 1e-6 * jnp.linalg.norm(r32.ravel())
        return cg(case32.ax_full, r32, tol=tol, max_iter=300,
                  dot=case32.dot()).x

    x, norms = ir_solve(case64.ax_full, f, inner, outer_iters=4)
    rel = float(norms[-1] / norms[0])
    assert rel < 1e-8, f"IR did not refine: {rel}"
    # solution error floor = spectral discretization error at n=6, not solver
    err = float(case64.solution_error(x, u_ex))
    assert err < 1e-5
