"""Shard-count invariance of the s-step halo windows (DESIGN.md §10).

The distributed s-step and Chebyshev drivers build their matrix-powers
windows by calling ``sstep_extend_field`` / ``sstep_extend_zfactor`` on a
*shard-local* grid with the neighbour shards' edge slabs as ``below`` /
``above`` ghosts.  The §10 correctness argument rests on these windows
being identical to the single-device ones for any shard count — block i's
window holds the same slabs whether its padding was gathered locally or
exchanged from a neighbour, with zeros (fields) / ones (z-factors) at the
global domain ends either way.  This test builds the ghosts in plain
numpy, splits over 1/2/4 z-shards, and requires bitwise equality of the
stacked per-shard windows against the global windows.
"""
import numpy as np
import pytest

from repro.kernels.nekbone_ax import sstep_extend_field, sstep_extend_zfactor

EX, EY, EZ, N3 = 2, 3, 8, 5
CASES = [(1, 1), (2, 2), (1, 2)]          # (sz, halo); halo <= min ez_local


@pytest.mark.parametrize("shards", [1, 2, 4])
@pytest.mark.parametrize("sz,halo", CASES)
def test_extend_field_shard_invariant(shards, sz, halo):
    rng = np.random.default_rng(11)
    eyex = EY * EX
    f = rng.normal(size=(EZ, eyex, N3)).astype(np.float32)
    want = np.asarray(sstep_extend_field(
        f.reshape(EZ * eyex, N3), (EX, EY, EZ), sz, halo))

    ez_l = EZ // shards
    # ghosts from the zero-padded global field: shard k's below/above are
    # the neighbour's edge slabs, exact zeros past the domain ends (the
    # padding gs.halo_exchange_z delivers there).
    fp = np.concatenate([np.zeros((halo, eyex, N3), f.dtype), f,
                         np.zeros((halo, eyex, N3), f.dtype)])
    got = np.concatenate([
        np.asarray(sstep_extend_field(
            f[k * ez_l:(k + 1) * ez_l].reshape(ez_l * eyex, N3),
            (EX, EY, ez_l), sz, halo,
            below=fp[k * ez_l:k * ez_l + halo],
            above=fp[(k + 1) * ez_l + halo:(k + 1) * ez_l + 2 * halo]))
        for k in range(shards)])
    assert np.array_equal(got, want)


@pytest.mark.parametrize("shards", [1, 2, 4])
@pytest.mark.parametrize("sz,halo", CASES)
def test_extend_zfactor_shard_invariant(shards, sz, halo):
    rng = np.random.default_rng(12)
    n = 4
    fz = rng.normal(size=(EZ, n)).astype(np.float32)
    want = np.asarray(sstep_extend_zfactor(fz, sz, halo))

    ez_l = EZ // shards
    fp = np.concatenate([np.ones((halo, n), fz.dtype), fz,
                         np.ones((halo, n), fz.dtype)])  # inert ones pad
    got = np.concatenate([
        np.asarray(sstep_extend_zfactor(
            fz[k * ez_l:(k + 1) * ez_l], sz, halo,
            below=fp[k * ez_l:k * ez_l + halo],
            above=fp[(k + 1) * ez_l + halo:(k + 1) * ez_l + 2 * halo]))
        for k in range(shards)])
    assert np.array_equal(got, want)


def test_extend_field_default_pad_matches_explicit_zeros():
    """``below=None`` at the global ends == explicit zero ghosts: the two
    forms the end shards may use are interchangeable."""
    rng = np.random.default_rng(13)
    eyex = EY * EX
    f2 = rng.normal(size=(EZ * eyex, N3)).astype(np.float32)
    z = np.zeros((2, eyex, N3), np.float32)
    a = np.asarray(sstep_extend_field(f2, (EX, EY, EZ), 2, 2))
    b = np.asarray(sstep_extend_field(f2, (EX, EY, EZ), 2, 2,
                                      below=z, above=z))
    assert np.array_equal(a, b)

    fz = rng.normal(size=(EZ, 4)).astype(np.float32)
    one = np.ones((2, 4), np.float32)
    za = np.asarray(sstep_extend_zfactor(fz, 2, 2))
    zb = np.asarray(sstep_extend_zfactor(fz, 2, 2, below=one, above=one))
    assert np.array_equal(za, zb)
