"""Loop-aware HLO analyzer: hand-counted toy modules."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo, parse_computations


def _compile(fn, *avals):
    return jax.jit(fn).lower(*avals).compile().as_text()


def test_plain_matmul():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    hlo = _compile(lambda a, b: a @ b, a, b)
    got = analyze_hlo(hlo)["dot_flops"]
    assert got == 2 * 64 * 128 * 32


def test_scan_multiplies_by_trip_count():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=7)[0]

    got = analyze_hlo(_compile(f, x, w))["dot_flops"]
    assert got == 7 * 2 * 64 ** 3


def test_nested_scans_compose():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci = jax.lax.scan(inner, c, None, length=3)[0]
            return jnp.tanh(ci), None
        return jax.lax.scan(outer, x, None, length=5)[0]

    got = analyze_hlo(_compile(f, x, w))["dot_flops"]
    assert got == 15 * 2 * 32 ** 3


def test_xla_cost_analysis_undercounts_loops():
    """Documents WHY the loop-aware analyzer exists: XLA's own
    cost_analysis returns the same flops for 1 and 8 iterations."""
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def make(L):
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            return jax.lax.scan(body, x, None, length=L)[0]
        return f

    def xla_flops(L):
        ca = jax.jit(make(L)).lower(x, w).compile().cost_analysis()
        if isinstance(ca, (list, tuple)):     # older jax wraps in a 1-list
            ca = ca[0]
        return ca["flops"]

    f1 = xla_flops(1)
    f8 = xla_flops(8)
    # identical up to loop-counter arithmetic — NOT x8
    assert f8 < 1.01 * f1, \
        "if this fails, XLA fixed trip-count costing — drop the analyzer " \
        "and use cost_analysis directly"


def test_parse_computations_shape():
    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    hlo = _compile(lambda a: jnp.tanh(a).sum(), x)
    comps, entry = parse_computations(hlo)
    assert entry is not None
    assert entry in comps
