"""Loop-aware HLO analyzer: hand-counted toy modules."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo, parse_computations


def _compile(fn, *avals):
    return jax.jit(fn).lower(*avals).compile().as_text()


def test_plain_matmul():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    hlo = _compile(lambda a, b: a @ b, a, b)
    got = analyze_hlo(hlo)["dot_flops"]
    assert got == 2 * 64 * 128 * 32


def test_scan_multiplies_by_trip_count():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=7)[0]

    got = analyze_hlo(_compile(f, x, w))["dot_flops"]
    assert got == 7 * 2 * 64 ** 3


def test_nested_scans_compose():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci = jax.lax.scan(inner, c, None, length=3)[0]
            return jnp.tanh(ci), None
        return jax.lax.scan(outer, x, None, length=5)[0]

    got = analyze_hlo(_compile(f, x, w))["dot_flops"]
    assert got == 15 * 2 * 32 ** 3


def test_xla_cost_analysis_undercounts_loops():
    """Documents WHY the loop-aware analyzer exists: XLA's own
    cost_analysis returns the same flops for 1 and 8 iterations."""
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def make(L):
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            return jax.lax.scan(body, x, None, length=L)[0]
        return f

    def xla_flops(L):
        ca = jax.jit(make(L)).lower(x, w).compile().cost_analysis()
        if isinstance(ca, (list, tuple)):     # older jax wraps in a 1-list
            ca = ca[0]
        return ca["flops"]

    f1 = xla_flops(1)
    f8 = xla_flops(8)
    # identical up to loop-counter arithmetic — NOT x8
    assert f8 < 1.01 * f1, \
        "if this fails, XLA fixed trip-count costing — drop the analyzer " \
        "and use cost_analysis directly"


def test_parse_computations_shape():
    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    hlo = _compile(lambda a: jnp.tanh(a).sum(), x)
    comps, entry = parse_computations(hlo)
    assert entry is not None
    assert entry in comps


# ---------------------------------------------------------------------------
# Cost extraction on the real pipeline entry points (ISSUE 10 satellite):
# the analyzer must produce loop-corrected numbers for every solver
# pipeline this repo ships, not just hand-built toy scans.  Each entry
# point compiles at a tiny interpret-mode case; the extraction must see
# nonzero dot flops and (single-device) no collectives.
# ---------------------------------------------------------------------------

import pytest  # noqa: E402


@pytest.fixture(scope="module")
def tiny_case():
    from repro.core.nekbone import NekboneCase

    return NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float32)


def _fused_v2_entry(case, precond_name, niter=2):
    from repro.core import precond as pc

    spec = case.precond_spec(precond_name) if precond_name else None

    def run(f):
        return pc.pcg_fused_v2_fixed_iters(
            f, D=case.D, g=case.g, grid=case.grid, niter=niter,
            precond=spec, mask=case.mask, c=case.c, interpret=True).x
    return run


def _entry_points(case):
    """name -> (fn, example_arg) for all six pipeline entry points."""
    from repro.core import cg as cg_mod
    from repro.core.cg_fused import cg_fused_fixed_iters

    _, f = case.manufactured()

    def reference(x):
        return cg_mod.cg_fixed_iters(case.ax_full, x, niter=2,
                                     dot=case.dot()).x

    def fused_v1(x):
        return cg_fused_fixed_iters(x, D=case.D, g=case.g, mask=case.mask,
                                    c=case.c, grid=case.grid, niter=2,
                                    interpret=True).x

    return {
        "reference": (reference, f),
        "fused_v1": (fused_v1, f),
        "fused_v2": (_fused_v2_entry(case, None), f),
        "fused_v2_jacobi": (_fused_v2_entry(case, "jacobi"), f),
        "fused_v2_cheb": (_fused_v2_entry(case, "cheb2"), f),
        "fused_v2_pmg": (_fused_v2_entry(case, "pmg"), f),
    }


@pytest.mark.parametrize("name", ["reference", "fused_v1", "fused_v2",
                                  "fused_v2_jacobi", "fused_v2_cheb",
                                  "fused_v2_pmg"])
def test_pipeline_entry_point_cost_extraction(tiny_case, name):
    fn, f = _entry_points(tiny_case)[name]
    got = analyze_hlo(_compile(fn, f))
    assert got["dot_flops"] > 0, f"{name}: no dot flops extracted"
    assert got["collectives"] == {}, \
        f"{name}: single-device pipeline shows collectives"


def test_sstep_cycle_traceables_cost_extraction(tiny_case):
    """The v3 matrix-powers pipeline is a host loop; its jittable halves
    are exported by sstep_cycle_traceables (obs/drift.py measures them
    the same way)."""
    from repro.core.cg_sstep import sstep_cycle_traceables

    case = tiny_case
    (powers, p_args), (update, u_args) = sstep_cycle_traceables(
        case.D, case.g, case.grid, s=2, sz=2)
    got_p = analyze_hlo(_compile(powers, *p_args))
    assert got_p["dot_flops"] > 0, "sstep powers: no dot flops"
    assert got_p["collectives"] == {}
    # the update kernel is the stream-bound half by design: merged
    # vector updates, zero tensor contractions (DESIGN.md §8)
    got_u = analyze_hlo(_compile(update, *u_args))
    assert got_u["dot_flops"] == 0
    assert got_u["collectives"] == {}


def test_reference_cg_flops_scale_with_niter(tiny_case):
    """Loop correction on a *real* pipeline: doubling the iteration count
    must double the extracted flops, which is exactly what raw XLA
    cost_analysis gets wrong on while bodies.  The reference CG is the
    entry point with a *static* trip count; the fused v2 driver threads
    ``niter`` as a runtime operand (its HLO is trip-count-invariant), so
    loop correction there is out of the analyzer's reach by design."""
    from repro.core import cg as cg_mod

    case = tiny_case
    _, f = case.manufactured()

    def entry(niter):
        def run(x):
            return cg_mod.cg_fixed_iters(case.ax_full, x, niter=niter,
                                         dot=case.dot()).x
        return run

    lo = analyze_hlo(_compile(entry(2), f))["dot_flops"]
    hi = analyze_hlo(_compile(entry(4), f))["dot_flops"]
    assert lo > 0
    assert hi == 2 * lo, f"niter 2->4 scaled dot flops {lo} -> {hi}, " \
        "expected exactly x2"
