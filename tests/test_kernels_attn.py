"""Pallas flash-attention kernel vs naive oracle."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref


def _qkv(rng, B, Hq, Hkv, Sq, Skv, d, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(B, Hq, Sq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Skv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Skv, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("kw", [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=16),
    dict(causal=True, softcap=30.0),
    dict(causal=True, window=8, softcap=50.0),
])
def test_flash_matches_naive(rng, kw):
    q, k, v = _qkv(rng, 2, 4, 2, 48, 48, 32)
    o_k = ops.flash_attention(q, k, v, block_q=16, block_k=16,
                              interpret=True, **kw)
    o_r = ref.attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5)


@pytest.mark.parametrize("Hq,Hkv", [(8, 8), (8, 4), (8, 2), (8, 1)])
def test_flash_gqa_groups(rng, Hq, Hkv):
    q, k, v = _qkv(rng, 1, Hq, Hkv, 32, 32, 16)
    o_k = ops.flash_attention(q, k, v, block_q=16, block_k=16, interpret=True)
    o_r = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5)


@pytest.mark.parametrize("Sq,Skv,bq,bk", [
    (40, 40, 16, 16),       # non-divisible (padding path)
    (33, 65, 16, 32),
    (8, 128, 8, 32),        # short q, long kv
    (128, 128, 128, 128),   # single block
])
def test_flash_shape_sweep(rng, Sq, Skv, bq, bk):
    q, k, v = _qkv(rng, 1, 2, 2, Sq, Skv, 16)
    o_k = ops.flash_attention(q, k, v, block_q=bq, block_k=bk, causal=False,
                              interpret=True)
    o_r = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-5)


def test_flash_q_offset_decode_chunk(rng):
    """Chunked prefill: second q chunk with q_offset matches the full run."""
    q, k, v = _qkv(rng, 1, 2, 2, 32, 32, 16)
    full = ref.attention_ref(q, k, v, causal=True)
    part = ops.flash_attention(q[:, :, 16:], k, v, q_offset=16,
                               block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(part), np.asarray(full[:, :, 16:]),
                               atol=2e-5)


def test_flash_bf16(rng):
    q, k, v = _qkv(rng, 1, 2, 1, 32, 32, 32, dtype=jnp.bfloat16)
    o_k = ops.flash_attention(q, k, v, block_q=16, block_k=16, interpret=True)
    o_r = ref.attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32))
    assert o_k.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(o_k, np.float32), np.asarray(o_r),
                               atol=3e-2)
