"""Pallas nekbone_ax kernel vs pure-jnp oracle: shape/dtype/block sweeps."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.sem import derivative_matrix
from repro.kernels import ops, ref


def _data(rng, E, n, dtype):
    u = jnp.asarray(rng.normal(size=(E, n, n, n)), dtype)
    g = jnp.asarray(rng.normal(size=(E, 6, n, n, n)), dtype)
    D = jnp.asarray(derivative_matrix(n), dtype)
    return u, D, g


@pytest.mark.parametrize("n", [2, 3, 4, 6, 8, 10, 12, 16])
def test_ax_kernel_n_sweep(rng, n):
    E = 8
    u, D, g = _data(rng, E, n, jnp.float32)
    w_k = ops.nekbone_ax(u, D, g, block_e=4, interpret=True)
    w_r = ref.nekbone_ax_ref(u, D, g)
    tol = 1e-5 * max(1.0, float(jnp.abs(w_r).max()))
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r), atol=tol)


@pytest.mark.parametrize("E,block_e", [(1, 1), (3, 2), (8, 8), (10, 4),
                                       (17, 8)])
def test_ax_kernel_block_sweep(rng, E, block_e):
    """Arbitrary element counts incl. non-divisible (padding path)."""
    n = 6
    u, D, g = _data(rng, E, n, jnp.float32)
    w_k = ops.nekbone_ax(u, D, g, block_e=block_e, interpret=True)
    w_r = ref.nekbone_ax_ref(u, D, g)
    assert w_k.shape == (E, n, n, n)
    tol = 1e-5 * max(1.0, float(jnp.abs(w_r).max()))
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r), atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ax_kernel_dtypes(rng, dtype):
    n, E = 10, 4
    u, D, g = _data(rng, E, n, dtype)
    w_k = ops.nekbone_ax(u, D, g, block_e=2, interpret=True)
    w_r = ref.nekbone_ax_ref(u.astype(jnp.float32), D.astype(jnp.float32),
                             g.astype(jnp.float32))
    assert w_k.dtype == dtype
    rtol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    scale = float(jnp.abs(w_r).max())
    np.testing.assert_allclose(np.asarray(w_k, np.float32),
                               np.asarray(w_r), atol=rtol * scale)


def test_ax_kernel_f64_interpret(rng, x64):
    """fp64 path (paper precision) validated through interpret mode."""
    n, E = 10, 4
    u, D, g = _data(rng, E, n, jnp.float64)
    w_k = ops.nekbone_ax(u, D, g, block_e=2, interpret=True)
    w_r = ref.nekbone_ax_ref(u, D, g)
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r),
                               rtol=1e-9, atol=1e-9)


def test_ax_autotuned_block(rng):
    """Default block_e autotune keeps the VMEM estimate under budget."""
    from repro.kernels.ops import _pick_block_e

    for n in (4, 8, 10, 12, 16):
        be = _pick_block_e(1024, n)
        n3p = -(-(n ** 3) // 128) * 128
        assert be >= 1
        assert 14 * n3p * 4 * be <= 8 * 2 ** 20
    n, E = 10, 16
    u, D, g = _data(rng, E, n, jnp.float32)
    w_k = ops.nekbone_ax(u, D, g, interpret=True)   # autotuned path
    w_r = ref.nekbone_ax_ref(u, D, g)
    tol = 1e-5 * max(1.0, float(jnp.abs(w_r).max()))
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r), atol=tol)
