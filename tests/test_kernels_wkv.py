"""Pallas WKV6 kernel (both variants) + chunked jnp path vs scan oracle."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.wkv6 import wkv6 as wkv6_kernel


def _data(rng, B, H, T, d, wmin=0.1, wmax=0.999):
    r = jnp.asarray(rng.normal(size=(B, H, T, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, T, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, T, d)), jnp.float32)
    w = jnp.asarray(rng.uniform(wmin, wmax, size=(B, H, T, d)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, d)), jnp.float32)
    return r, k, v, w, u


@pytest.mark.parametrize("variant", ["sequential", "chunked"])
@pytest.mark.parametrize("T,bt", [(32, 16), (48, 16), (64, 8), (20, 16)])
def test_wkv6_kernel_vs_oracle(rng, variant, T, bt):
    r, k, v, w, u = _data(rng, 2, 2, T, 16)
    o_r, S_r = ref.wkv6_ref(r, k, v, w, u, return_state=True)
    o_k, S_k = wkv6_kernel(r, k, v, w, u, return_state=True, block_t=bt,
                           variant=variant, interpret=True)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S_k), np.asarray(S_r),
                               rtol=1e-3, atol=1e-4)


def test_wkv6_kernel_initial_state(rng):
    """Chunk continuation: state from first half feeds the second half."""
    B, H, T, d = 1, 2, 32, 16
    r, k, v, w, u = _data(rng, B, H, T, d)
    full = ref.wkv6_ref(r, k, v, w, u)
    h = T // 2
    o1, S1 = wkv6_kernel(r[:, :, :h], k[:, :, :h], v[:, :, :h], w[:, :, :h],
                         u, return_state=True, block_t=16, interpret=True)
    o2 = wkv6_kernel(r[:, :, h:], k[:, :, h:], v[:, :, h:], w[:, :, h:], u,
                     initial_state=S1, block_t=16, interpret=True)
    got = jnp.concatenate([o1, o2], axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=1e-3, atol=1e-4)


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 2 ** 16),
       wmin=st.floats(0.066, 0.5))     # RWKV6 decay floor exp(-exp(1))
def test_wkv6_chunked_jnp_decay_range(seed, wmin):
    """Training-path chunked formulation stays accurate across the decay
    range the model can actually produce (w~ clipped to [-8, 1])."""
    rng = np.random.default_rng(seed)
    r, k, v, w, u = _data(rng, 1, 1, 64, 8, wmin=wmin, wmax=0.9999)
    o_r = ref.wkv6_ref(r, k, v, w, u)
    o_c = ref.wkv6_chunked(r, k, v, w, u, chunk=16)
    scale = float(jnp.abs(o_r).max()) + 1e-6
    assert float(jnp.abs(o_c - o_r).max()) < 2e-3 * scale


def test_wkv6_chunked_jnp_grad(rng):
    r, k, v, w, u = _data(rng, 1, 2, 32, 8)
    g = jax.grad(lambda r_: ref.wkv6_chunked(r_, k, v, w, u).sum())(r)
    assert bool(jnp.isfinite(g).all())
    # grads of the decay path too
    gw = jax.grad(lambda w_: ref.wkv6_chunked(r, k, v, w_, u).sum())(w)
    assert bool(jnp.isfinite(gw).all())


def test_wkv6_state_linearity(rng):
    """The recurrence is linear in v: doubling v doubles output."""
    r, k, v, w, u = _data(rng, 1, 1, 24, 8)
    o1 = ref.wkv6_chunked(r, k, v, w, u)
    o2 = ref.wkv6_chunked(r, k, 2.0 * v, w, u)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(2 * o1),
                               rtol=1e-4, atol=1e-5)
