"""Contraction-layout parity (DESIGN.md §11.2, kernels/nekbone_ax.py).

Every (layout x grid_order) configuration of the tensor-product kernels
must be *bitwise* identical at fp64 — the layouts only reshape/transpose
around the same ``jnp.dot`` contractions, they never reassociate a
floating-point sum, so the autotuner is free to pick any point of the
sweep space without perturbing the solver's round-off trajectory.  The
checks run through the full ops-level wrappers (plane stitch, halo
windows, Gram blocks included) on randomized grids.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.gs import ds_sum_local
from repro.core.nekbone import NekboneCase
from repro.kernels import ops
from repro.kernels.nekbone_ax import GRID_ORDERS, LAYOUTS


CONFIGS = [(ly, go) for ly in LAYOUTS for go in GRID_ORDERS]


def _continuous_field(rng, case):
    u = jnp.asarray(rng.normal(size=case.mask.shape), case.dtype)
    return ds_sum_local(u, case.grid) * case.mask


def _random_case(seed):
    r = np.random.default_rng(seed)
    grid = tuple(int(v) for v in r.integers(1, 4, size=3))
    n = int(r.integers(3, 7))
    return NekboneCase(n=n, grid=grid, dtype=jnp.float64)


def test_layout_space_is_what_design_documents():
    assert LAYOUTS == ("fold", "dng", "dnt")
    assert GRID_ORDERS == ("parallel", "arbitrary")


@pytest.mark.parametrize("seed", [0, 1])
def test_slab_kernel_bitwise_across_configs(rng, x64, seed):
    case = _random_case(seed)
    p_prev = _continuous_field(rng, case)
    r = _continuous_field(rng, case)

    ref = None
    for layout, grid_order in CONFIGS:
        p_out, w, pap = ops.nekbone_ax_dots_slab(
            p_prev, r, case.D, case.g, case.grid, beta=0.37,
            layout=layout, grid_order=grid_order, interpret=True)
        got = (np.asarray(p_out), np.asarray(w), float(pap))
        if ref is None:
            ref = got
            continue
        np.testing.assert_array_equal(got[0], ref[0],
                                      err_msg=f"{layout=} {grid_order=}")
        np.testing.assert_array_equal(got[1], ref[1],
                                      err_msg=f"{layout=} {grid_order=}")
        assert got[2] == ref[2], (layout, grid_order)


@pytest.mark.parametrize("seed", [0, 1])
def test_powers_kernel_bitwise_across_configs(rng, x64, seed):
    case = _random_case(seed)
    p = _continuous_field(rng, case)
    r = _continuous_field(rng, case)

    ref = None
    for layout, grid_order in CONFIGS:
        basis, gram = ops.nekbone_ax_powers(
            p, r, case.D, case.g, case.grid, s=2, theta=1.3,
            layout=layout, grid_order=grid_order, interpret=True)
        got = (np.asarray(basis), np.asarray(gram))
        if ref is None:
            ref = got
            continue
        np.testing.assert_array_equal(got[0], ref[0],
                                      err_msg=f"{layout=} {grid_order=}")
        np.testing.assert_array_equal(got[1], ref[1],
                                      err_msg=f"{layout=} {grid_order=}")


@pytest.mark.parametrize("seed", [0, 1])
def test_cheb_kernel_bitwise_across_configs(rng, x64, seed):
    from repro.core import precond as pc

    case = _random_case(seed)
    r = _continuous_field(rng, case)
    coef = pc.cheb_scalars(2, 0.1, 1.9)

    ref = None
    for layout, grid_order in CONFIGS:
        out = ops.nekbone_cheb_precond(
            r, case.D, case.g, coef, case.grid, k=2,
            layout=layout, grid_order=grid_order, interpret=True)
        got = tuple(np.asarray(o) for o in out) \
            if isinstance(out, tuple) else (np.asarray(out),)
        if ref is None:
            ref = got
            continue
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(a, b,
                                          err_msg=f"{layout=} {grid_order=}")


def test_full_solver_bitwise_across_configs(x64):
    """End to end: the whole v2 fixed-iteration solve is bitwise invariant
    to the configuration the autotuner picks."""
    from repro.core.cg_fused import cg_fused_v2_fixed_iters

    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float64)
    _, b = case.manufactured()

    ref = None
    for layout, grid_order in CONFIGS:
        res = cg_fused_v2_fixed_iters(
            b, D=case.D, g=case.g, grid=case.grid, niter=3,
            mask=case.mask, c=case.c, layout=layout,
            grid_order=grid_order, interpret=True)
        x = np.asarray(res.x)
        if ref is None:
            ref = x
            continue
        np.testing.assert_array_equal(x, ref,
                                      err_msg=f"{layout=} {grid_order=}")
