"""Per-arch smoke tests + decode-path consistency (all 10 assigned archs)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import model as M

ALL_ARCHS = sorted(ARCHS)


def _extra(cfg, B, key):
    if cfg.img_tokens:
        return {"img_embeds": 0.1 * jax.random.normal(
            key, (B, cfg.img_tokens, cfg.d_model))}
    if cfg.enc_layers:
        return {"audio_embeds": 0.1 * jax.random.normal(
            key, (B, cfg.audio_ctx, cfg.d_model))}
    return None


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_forward_and_grad(name):
    """Reduced config: one train step's forward+grad, shapes + finiteness."""
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    B, S = 2, 32
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    extra = _extra(cfg, B, key)

    logits = M.forward(params, cfg, tokens[:, :-1], extra)
    S_total = S + (cfg.img_tokens or 0)
    assert logits.shape == (B, S_total, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, {"tokens": tokens}, extra))(params)
    assert bool(jnp.isfinite(loss))
    assert np.isfinite(float(loss)) and float(loss) > 0
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, "dead gradients"


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_matches_forward(name):
    """prefill(prompt) + decode steps == full forward logits."""
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    B, S = 2, 20
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    extra = _extra(cfg, B, key)
    off = cfg.img_tokens or 0

    full = M.forward(params, cfg, tokens, extra)
    k0 = S - 3
    logits_p, cache = M.prefill(params, cfg, tokens[:, :k0], extra,
                                max_len=S + off)
    errs = [float(jnp.abs(logits_p[:, -1] - full[:, k0 - 1 + off]).max())]
    for i in range(k0, S):
        logits_d, cache = M.decode_step(
            params, cfg, tokens[:, i:i + 1], cache,
            jnp.asarray(i + off, jnp.int32))
        errs.append(float(jnp.abs(logits_d[:, 0] - full[:, i + off]).max()))
    scale = float(jnp.abs(full).max()) + 1e-6
    assert max(errs) < 2e-4 * max(scale, 10.0), f"decode drift: {errs}"


def test_gemma2_softcaps_applied():
    cfg = ARCHS["gemma2-27b"].reduced()
    assert cfg.logit_softcap == 30.0
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    # blow up the lm head weights: logits must stay within the softcap
    params["embed"] = params["embed"] * 100.0
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    logits = M.forward(params, cfg, tokens)
    assert float(jnp.abs(logits).max()) <= 30.0 + 1e-3


def test_window_pattern_gemma2():
    lw = ARCHS["gemma2-27b"].layer_windows()
    assert lw[0] == 4096 and lw[1] == 1 << 30
    assert len(lw) == 46


def test_hymba_window_pattern():
    lw = ARCHS["hymba-1.5b"].layer_windows()
    assert lw[0] == 1 << 30 and lw[16] == 1 << 30 and lw[31] == 1 << 30
    assert lw[1] == 1024


def test_long500k_rule():
    from repro.configs import cells

    skipped = {(a, s) for a, s, sk in cells() if sk}
    run = {(a, s) for a, s, sk in cells() if not sk and s == "long_500k"}
    assert ("rwkv6-1.6b", "long_500k") in run
    assert ("hymba-1.5b", "long_500k") in run
    assert ("gemma2-27b", "long_500k") in run       # alternating local/global
    for a in ("codeqwen1.5-7b", "nemotron-4-340b", "qwen2.5-14b",
              "llava-next-mistral-7b", "whisper-large-v3",
              "qwen3-moe-30b-a3b", "arctic-480b"):
        assert (a, "long_500k") in skipped


def test_param_counts_sane():
    """Analytic N within ~25% of the published sizes."""
    expect = {"rwkv6-1.6b": 1.6e9, "gemma2-27b": 27e9, "codeqwen1.5-7b": 7e9,
              "nemotron-4-340b": 340e9, "qwen2.5-14b": 14e9,
              "llava-next-mistral-7b": 7e9, "qwen3-moe-30b-a3b": 30e9,
              "arctic-480b": 480e9, "hymba-1.5b": 1.5e9}
    for name, want in expect.items():
        got = ARCHS[name].param_count()
        assert 0.7 * want < got < 1.35 * want, (name, got, want)
    # MoE active params
    a3b = ARCHS["qwen3-moe-30b-a3b"].active_param_count()
    assert 2e9 < a3b < 5e9, a3b
