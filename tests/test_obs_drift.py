"""obs/drift.py: jaxpr stream charging + the cost-model drift gate.

The full three-pipeline sweep lives in the obs-smoke CI leg
(benchmarks/obs_smoke.py); here the charging primitives are checked on
hand-counted programs and the gate semantics on one cheap pipeline.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.obs import drift


# ---------------------------------------------------------------------------
# charge_streams / measure_* on hand-counted programs
# ---------------------------------------------------------------------------

def test_charge_streams_counts_leaf_operands():
    def f(a, b):
        return a + b  # one leaf eqn: reads both, writes one

    a = jnp.zeros((8,), jnp.float32)
    r, w = drift.measure_call_bytes(f, a, a)
    assert r == 2 * 8 * 4
    assert w == 8 * 4


def test_charge_streams_descends_structural_eqns():
    @jax.jit
    def inner(a):
        return a * 2.0

    def f(a):
        return inner(a) + 1.0

    a = jnp.zeros((4,), jnp.float32)
    r, w = drift.measure_call_bytes(f, a)
    # pjit boundary must not be double-charged: the mul inside plus the
    # add outside write 16 bytes each; reads are those two 16-byte
    # operands plus the scalar literals (4 bytes apiece)
    assert w == 2 * 16
    assert 2 * 16 <= r <= 2 * 16 + 16


def test_measure_iteration_bytes_charges_loop_body():
    def f(a):
        def body(c, _):
            return c + 1.0, None
        return jax.lax.scan(body, a, None, length=5)[0]

    a = jnp.zeros((16,), jnp.float32)
    r, w = drift.measure_iteration_bytes(f, a)
    # ONE iteration's body, not 5x: the add reads carry + scalar
    assert w == 16 * 4
    assert r >= 16 * 4
    assert r < 2 * 16 * 4 + 8  # carry + broadcast scalar, nothing else


def test_measure_iteration_bytes_requires_a_loop():
    with pytest.raises(ValueError):
        drift.measure_iteration_bytes(lambda a: a + 1.0,
                                      jnp.zeros((4,), jnp.float32))


# ---------------------------------------------------------------------------
# report / gate semantics
# ---------------------------------------------------------------------------

def test_unknown_pipeline_raises():
    with pytest.raises(ValueError):
        drift.check_bytes("made_up_pipeline")
    with pytest.raises(ValueError):
        drift.check_collectives("made_up_pipeline")


def test_report_to_dict_schema():
    row = drift.DriftRow(pipeline="p", check="c", measured=1, expected=1,
                         ok=True, ratio=1.0, band=(0.9, 1.1))
    rep = drift.DriftReport(rows=[row])
    assert rep.ok and rep.failures() == []
    d = rep.to_dict()
    assert d["schema"] == "model-drift/1"
    assert d["ok"] is True
    assert d["rows"][0]["pipeline"] == "p"
    assert "provenance" in d


def test_assert_no_drift_raises_on_failure():
    bad = drift.DriftRow(pipeline="p", check="c", measured=2, expected=1,
                         ok=False, detail="measured 2x the book")
    with pytest.raises(drift.ModelDriftError) as ei:
        drift.assert_no_drift(drift.DriftReport(rows=[bad]))
    assert "p/c" in str(ei.value)
    assert "measured 2x the book" in str(ei.value)


def test_assert_no_drift_passes_clean_report():
    good = drift.DriftRow(pipeline="p", check="c", measured=1, expected=1,
                          ok=True)
    rep = drift.assert_no_drift(drift.DriftReport(rows=[good]))
    assert rep.ok


# ---------------------------------------------------------------------------
# one real pipeline end to end (the other two + the byte bands run in the
# obs-smoke CI leg; collectives here are make_jaxpr-only and cheap)
# ---------------------------------------------------------------------------

def test_fused_v2_collective_contract():
    row = drift.check_collectives("fused_v2")
    assert row.ok, row.detail
    assert row.measured == {}  # single-device: collective-free


def test_sstep_collective_contract():
    row = drift.check_collectives("sstep_v3")
    assert row.ok, row.detail
    assert row.measured["cycle"] == {"ppermute": 2, "psum": 1}
    assert row.measured["update"] == {}
