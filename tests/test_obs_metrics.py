"""obs/metrics.py: histograms, service metrics, per-solve telemetry."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import trace
from repro.obs.metrics import Histogram, ServiceMetrics, capture_solve


# ---------------------------------------------------------------------------
# Histogram
# ---------------------------------------------------------------------------

def test_histogram_buckets_and_stats():
    h = Histogram((1.0, 10.0))
    for v in (0.5, 5.0, 5.0, 50.0):
        h.record(v)
    snap = h.snapshot()
    assert snap["buckets"] == {"le_1": 1, "le_10": 2, "inf": 1}
    assert snap["count"] == 4
    assert snap["min"] == 0.5 and snap["max"] == 50.0
    assert snap["mean"] == pytest.approx(60.5 / 4)


def test_histogram_empty_snapshot():
    snap = Histogram((1.0,)).snapshot()
    assert snap["count"] == 0
    assert snap["mean"] is None and snap["min"] is None


def test_histogram_requires_bounds():
    with pytest.raises(ValueError):
        Histogram(())


def test_histogram_boundary_value_goes_low():
    h = Histogram((1.0, 10.0))
    h.record(1.0)  # upper edges are inclusive
    assert h.snapshot()["buckets"]["le_1"] == 1


# ---------------------------------------------------------------------------
# ServiceMetrics
# ---------------------------------------------------------------------------

def test_service_metrics_queue_and_dispatch():
    m = ServiceMetrics()
    m.observe_submit(1)
    m.observe_submit(2)
    m.observe_submit(3)
    m.observe_depth(0)
    bucket = ((8, 5), "f32")
    m.observe_dispatch(bucket, batch=2, max_b=4, wall_us=2_000.0)
    m.observe_dispatch(bucket, batch=1, max_b=4, wall_us=20_000.0)
    snap = m.snapshot()
    assert snap["submitted"] == 3
    assert snap["queue_depth"] == 0
    assert snap["queue_high_water"] == 3
    assert snap["dispatches"] == 2
    assert snap["requests_served"] == 3
    assert snap["latency_ms"]["count"] == 2
    assert snap["occupancy"]["buckets"]["le_0.25"] == 1  # batch 1 of 4
    assert snap["occupancy"]["buckets"]["le_0.5"] == 1   # batch 2 of 4
    per = snap["per_bucket"]
    assert list(per) == [repr(bucket)]  # JSON-safe keys
    assert per[repr(bucket)]["latency_ms"]["count"] == 2


def test_service_metrics_emit_to_active_recorder():
    m = ServiceMetrics()
    with trace.recording() as rec:
        m.observe_submit(5)
        m.observe_dispatch(("b",), batch=3, max_b=4, wall_us=1.0)
    assert rec.gauges["service.queue_depth"] == 5
    assert rec.counters["service.dispatches"] == 1
    assert rec.counters["service.requests"] == 3


# ---------------------------------------------------------------------------
# capture_solve
# ---------------------------------------------------------------------------

class _FakeResult:
    pipeline = "fused_v2"
    precond = None
    iters_taken = np.asarray([3, 5])
    achieved_rtol = jnp.asarray([1e-9, 1e-7])


def test_capture_solve_reduces_over_batch():
    tel = capture_solve(_FakeResult(), route="block", b=2, niter=5,
                        tol=None, wall_us=123.4,
                        phases={"dispatch": 123.4},
                        autotune={"hits": 1, "misses": 0})
    assert tel.iters == 5                      # max over lanes
    assert tel.achieved_rtol == pytest.approx(1e-7)  # worst lane
    assert tel.route == "block" and tel.pipeline == "fused_v2"
    assert tel.autotune == {"hits": 1, "misses": 0}
    assert tel.provenance["machine"] == trace.machine_tag()
    d = tel.to_dict()
    assert d["wall_us"] == pytest.approx(123.4)
    assert d["phases"] == {"dispatch": 123.4}


def test_solve_case_attaches_telemetry_only_when_tracing():
    from repro.core.nekbone import NekboneCase

    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float32,
                       ax_impl="pallas_fused_cg_v2")
    _, f = case.manufactured()
    off = case.solve(f, niter=3)
    assert off.telemetry is None
    with trace.recording() as rec:
        on = case.solve(f, niter=3)
    tel = on.telemetry
    assert tel is not None
    assert tel.iters == 3
    assert tel.wall_us > 0
    assert tel.route == "v2"
    assert rec.counters.get("solves") == 1
    assert "solve" in [r["name"] for r in rec.records
                       if r["type"] == "span"]
    # bitwise: instrumentation must not perturb the solve
    assert np.asarray(off.x).tobytes() == np.asarray(on.x).tobytes()
