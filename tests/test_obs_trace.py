"""obs/trace.py: recorder lifecycle, span records, JSONL schema.

jax-free on purpose — the trace surface must import and run without jax
so log consumers (and the tracing-off hot path) never pay for it.
"""
import json

import numpy as np
import pytest

from repro.obs import trace


# ---------------------------------------------------------------------------
# off-path contract: no recorder, no allocation
# ---------------------------------------------------------------------------

def test_active_is_none_by_default():
    assert trace.active() is None


def test_module_span_is_null_singleton_when_off():
    s1 = trace.span("anything", attr=1)
    s2 = trace.span("else")
    assert s1 is trace.NULL_SPAN and s2 is trace.NULL_SPAN
    with s1:
        pass  # enters and exits without effect


def test_module_count_gauge_event_noop_when_off():
    trace.count("c")
    trace.gauge("g", 2.0)
    trace.event("e", k=1)  # nothing to assert beyond "does not raise"


def test_profiler_annotation_null_without_env(monkeypatch):
    monkeypatch.delenv("REPRO_PROFILE", raising=False)
    assert trace.profiler_annotation("x") is trace.NULL_SPAN


# ---------------------------------------------------------------------------
# recording
# ---------------------------------------------------------------------------

def test_recording_activates_and_restores():
    assert trace.active() is None
    with trace.recording() as rec:
        assert trace.active() is rec
        with trace.recording() as inner:   # nested shadows
            assert trace.active() is inner
        assert trace.active() is rec
    assert trace.active() is None


def test_span_records_on_exit_with_depth_and_attrs():
    with trace.recording() as rec:
        with rec.span("outer", a=1):
            with rec.span("inner"):
                pass
    # completion order: inner closes first
    names = [(r["name"], r["depth"]) for r in rec.records]
    assert names == [("inner", 1), ("outer", 0)]
    outer = rec.records[1]
    assert outer["attrs"] == {"a": 1}
    assert outer["dur_us"] >= 0
    assert outer["type"] == "span"


def test_counters_and_gauges_land_in_summary():
    with trace.recording() as rec:
        rec.count("solves")
        rec.count("solves")
        rec.count("bytes", 7)
        rec.gauge("depth", 3)
        rec.gauge("depth", 1)  # last value wins
    s = rec.summary()
    assert s["counters"] == {"solves": 2, "bytes": 7}
    assert s["gauges"] == {"depth": 1}
    assert s["spans"] == 0 and s["events"] == 0


def test_lines_are_valid_jsonl_with_header_and_summary():
    with trace.recording(meta={"case": "unit"}) as rec:
        with rec.span("s", x=2):
            rec.event("ev", y=np.int64(3))  # numpy attrs must serialize
    lines = rec.lines()
    head = json.loads(lines[0])
    tail = json.loads(lines[-1])
    assert head["type"] == "header"
    assert head["schema"] == trace.TRACE_SCHEMA
    assert head["meta"] == {"case": "unit"}
    assert set(head["provenance"]) >= {"machine", "python"}
    assert tail["type"] == "summary"
    assert tail["spans"] == 1 and tail["events"] == 1
    assert trace.validate_trace_lines(lines) == []


def test_write_and_validate_file(tmp_path):
    path = tmp_path / "sub" / "t.trace.jsonl"
    with trace.recording(path) as rec:
        with rec.span("s"):
            pass
    assert path.exists()  # parent dir created
    assert trace.validate_trace_file(path) == []
    # validation actually rejects: clobber the header schema
    lines = path.read_text().splitlines()
    head = json.loads(lines[0])
    head["schema"] = "not-a-trace/9"
    path.write_text("\n".join([json.dumps(head)] + lines[1:]) + "\n")
    assert trace.validate_trace_file(path) != []


def test_recording_writes_file_on_exception(tmp_path):
    path = tmp_path / "fail.trace.jsonl"
    with pytest.raises(RuntimeError):
        with trace.recording(path) as rec:
            with rec.span("doomed"):
                pass
            raise RuntimeError("solve blew up")
    assert path.exists()  # a failing solve still leaves its evidence
    assert trace.validate_trace_file(path) == []


# ---------------------------------------------------------------------------
# provenance
# ---------------------------------------------------------------------------

def test_machine_tag_is_hostname_free():
    import platform

    tag = trace.machine_tag()
    assert platform.node() not in tag or platform.node() == ""
    assert tag.startswith(platform.system().lower())


def test_provenance_keys():
    prov = trace.provenance()
    assert {"machine", "python", "backend"} <= set(prov)
