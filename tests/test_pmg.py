"""p-multigrid preconditioner (core/pmg.py + precond._pcg_pmg, ISSUE 9).

Pins, in order:

* the degree ladder and the transfer-matrix algebra (polynomial
  exactness up to the coarse degree, interpolation-sense
  restrict∘prolong identity, endpoint 0/1 rows);
* the Pallas interpolation kernel against the dense XLA reference —
  fp64 BITWISE, across slab splits (same dot_general pattern by
  construction);
* the fused V-cycle PCG driver against the XLA reference V-cycle
  through reference PCG (trajectory parity, the same way the Chebyshev
  driver was verified);
* SPD-contract evidence: symmetry of the reference cycle in the
  c-weighted inner product and positivity of <r, M r>;
* the iteration-count acceptance: pmg beats cheb4 on a shared case.

The E=1024/n=10 paper-case acceptance (<= half of cheb4's iterations to
rtol 1e-8) runs in benchmarks/pmg_smoke.py and the pcg_pmg bench rows.
"""
import numpy as np
import pytest

import jax.numpy as jnp

import repro.core.cg as cg_mod
import repro.core.pmg as pmg
import repro.core.precond as pc
from repro.core.gs import ds_sum_local
from repro.core.nekbone import NekboneCase
from repro.kernels import nekbone_ax as ax_kernels

GRID = (2, 2, 4)


def _case(n=5, grid=GRID):
    return NekboneCase(n=n, grid=grid, dtype=jnp.float64,
                       ax_impl="pallas_fused_cg_v2")


def _masked_rhs(rng, case):
    u = jnp.asarray(rng.normal(size=case.mask.shape), case.dtype)
    return ds_sum_local(u * case.mask, case.grid) * case.mask


# ---------------------------------------------------------------------------
# ladder + transfer matrices (satellite 3)
# ---------------------------------------------------------------------------

def test_pmg_degree_ladder():
    from repro.core.cost import pmg_degrees

    assert pmg_degrees(10) == (10, 5, 3, 2)
    assert pmg_degrees(5) == (5, 3, 2)
    assert pmg_degrees(6) == (6, 3, 2)
    assert pmg_degrees(2) == (2,)


def test_interp_matrix_polynomial_exactness():
    """J (nf, nc) reproduces polynomials up to degree nc-1 exactly, and
    J^T-restriction of a fine polynomial sampled back is exact for the
    identity composition R_mat @ P_mat on the coarse grid."""
    from repro.core.sem import gll_points_weights

    for nf, nc in ((10, 5), (5, 3), (3, 2), (7, 4)):
        J = pmg.gll_interp_matrix(nf, nc)
        xf = np.asarray(gll_points_weights(nf)[0], np.float64)
        xc = np.asarray(gll_points_weights(nc)[0], np.float64)
        for p in range(nc):                # all polynomials in the space
            np.testing.assert_allclose(J @ xc ** p, xf ** p,
                                       rtol=0, atol=5e-14)


def test_interp_matrix_endpoint_rows_exact():
    for nf, nc in ((10, 5), (5, 3), (3, 2)):
        J = pmg.gll_interp_matrix(nf, nc)
        e0 = np.zeros(nc)
        e0[0] = 1.0
        eN = np.zeros(nc)
        eN[-1] = 1.0
        np.testing.assert_array_equal(J[0], e0)     # exact 0/1, not approx
        np.testing.assert_array_equal(J[-1], eN)


def test_prolong_then_restrict_identity_on_coarse():
    """Interpolation-sense identity: sampling the prolonged field back on
    the coarse GLL grid recovers it exactly — gll_interp_matrix(nc, nf) @
    gll_interp_matrix(nf, nc) == I (the fine space contains the coarse
    polynomials)."""
    for nf, nc in ((10, 5), (5, 3), (3, 2)):
        back = pmg.gll_interp_matrix(nc, nf) @ pmg.gll_interp_matrix(nf, nc)
        np.testing.assert_allclose(back, np.eye(nc), rtol=0, atol=5e-14)


def test_interp3_prolong_then_sample_back_identity_3d(x64, rng):
    """The 3-D composition through interp3 (and hence the kernel path)
    inherits the 1-D identity."""
    nf, nc = 5, 3
    E = 8
    ec = jnp.asarray(rng.normal(size=(E, nc, nc, nc)))
    up = pmg.interp3(ec, jnp.asarray(pmg.gll_interp_matrix(nf, nc)))
    back = pmg.interp3(up, jnp.asarray(pmg.gll_interp_matrix(nc, nf)))
    np.testing.assert_allclose(np.asarray(back), np.asarray(ec),
                               rtol=0, atol=1e-13)


# ---------------------------------------------------------------------------
# Pallas interpolation kernel vs dense XLA reference (satellite 3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sz", [1, 2, 4])
@pytest.mark.parametrize("nf,nc", [(5, 3), (3, 2), (10, 5)])
def test_interp_kernel_bitwise_vs_reference(x64, rng, sz, nf, nc):
    """Restriction AND prolongation directions, every slab split: the
    kernel issues the same dot_general contractions as interp3, so fp64
    results are bitwise identical."""
    ex, ey, ez = GRID
    E = ex * ey * ez
    u = jnp.asarray(rng.normal(size=(E, nf, nf, nf)))
    J = jnp.asarray(pmg.gll_interp_matrix(nf, nc))
    # restriction direction: contract fine axes with J's rows (mt = J)
    ref = pmg.interp3(u, J.T)
    got = ax_kernels.nekbone_interp_pallas(
        u.reshape(E, nf ** 3), J, nin=nf, nout=nc, grid=GRID, sz=sz,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref).reshape(E, nc ** 3))
    # prolongation direction (mt = J.T)
    ec = jnp.asarray(rng.normal(size=(E, nc, nc, nc)))
    refp = pmg.interp3(ec, J)
    gotp = ax_kernels.nekbone_interp_pallas(
        ec.reshape(E, nc ** 3), J.T, nin=nc, nout=nf, grid=GRID, sz=sz,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(gotp),
                                  np.asarray(refp).reshape(E, nf ** 3))


def test_ops_nekbone_interp_wrapper(x64, rng):
    """The ops-layer wrapper takes the natural (n_out, n_in) matrix and
    natural-shape fields."""
    from repro.kernels.ops import nekbone_interp

    ex, ey, ez = GRID
    E = ex * ey * ez
    nf, nc = 5, 3
    u = jnp.asarray(rng.normal(size=(E, nf, nf, nf)))
    R = jnp.asarray(pmg.gll_interp_matrix(nf, nc)).T     # (nc, nf)
    got = nekbone_interp(u, R, GRID, interpret=True)
    ref = pmg.interp3(u, R)
    assert got.shape == (E, nc, nc, nc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# spec construction + spellings
# ---------------------------------------------------------------------------

def test_make_preconditioner_pmg_spellings(x64):
    case = _case()
    spec = pc.make_preconditioner("pmg", D=case.D, g=case.g, grid=case.grid,
                                  mask=case.mask, c=case.c)
    assert isinstance(spec, pc.PMGPrecond)
    assert spec.ns == (5, 3, 2) and spec.k == pc.PMG_DEFAULT_K
    spec3 = pc.make_preconditioner("pmg[cheb3]", D=case.D, g=case.g,
                                   grid=case.grid, mask=case.mask, c=case.c)
    assert spec3.k == 3
    with pytest.raises(ValueError, match="pmg spellings"):
        pc.make_preconditioner("pmg[cheb]", D=case.D, g=case.g,
                               grid=case.grid, mask=case.mask, c=case.c)
    with pytest.raises(ValueError, match="pmg spellings"):
        pc.make_preconditioner("pmgX", D=case.D, g=case.g, grid=case.grid,
                               mask=case.mask, c=case.c)


def test_pmg_needs_coarsenable_degree(x64):
    case = _case(n=2)
    with pytest.raises(ValueError, match="n >= 3"):
        pc.make_preconditioner("pmg", D=case.D, g=case.g, grid=case.grid,
                               mask=case.mask, c=case.c)


# ---------------------------------------------------------------------------
# SPD contract + reference-cycle algebra
# ---------------------------------------------------------------------------

def test_vcycle_reference_symmetric_positive(x64, rng):
    case = _case()
    spec = case.precond_spec("pmg")
    M = pmg.pmg_vcycle_reference(spec, D=case.D, g=case.g, grid=case.grid,
                                 mask=case.mask, c=case.c)
    u = _masked_rhs(rng, case)
    v = _masked_rhs(rng, case)
    dot = case.dot()
    a1 = float(dot(u, M(v)))
    a2 = float(dot(M(u), v))
    assert abs(a1 - a2) <= 1e-12 * abs(a1)
    assert float(dot(u, M(u))) > 0.0


# ---------------------------------------------------------------------------
# fused driver parity + acceptance
# ---------------------------------------------------------------------------

def test_pcg_pmg_matches_reference_pcg(x64, rng):
    """Fused pmg-PCG trajectory == XLA reference V-cycle under reference
    PCG, to fp64 round-off (the Chebyshev driver's verification pattern)."""
    case = _case()
    f = _masked_rhs(rng, case)
    spec = case.precond_spec("pmg")
    M = pmg.pmg_vcycle_reference(spec, D=case.D, g=case.g, grid=case.grid,
                                 mask=case.mask, c=case.c)
    ref = cg_mod.cg(case.ax_full, f, dot=case.dot(), max_iter=8, tol=0.0,
                    precond=M)
    res = pc.pcg_fused_v2_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                                      niter=8, precond=spec, mask=case.mask,
                                      c=case.c, interpret=True)
    hr = np.asarray(ref.rnorm_history)[:9]
    hf = np.asarray(res.rnorm_history)[:9]
    np.testing.assert_allclose(hf, hr, rtol=1e-10)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(ref.x),
                               rtol=0, atol=1e-10)


@pytest.mark.parametrize("sz", [1, 2, 4])
def test_pcg_pmg_invariant_to_slab_split(x64, rng, sz):
    """The level-0 slab split only changes fp associations."""
    case = _case()
    f = _masked_rhs(rng, case)
    spec = case.precond_spec("pmg")
    base = pc.pcg_fused_v2_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=6, precond=spec,
        mask=case.mask, c=case.c, interpret=True, sz=4, cheb_sz=4)
    got = pc.pcg_fused_v2_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=6, precond=spec,
        mask=case.mask, c=case.c, interpret=True, sz=sz, cheb_sz=sz)
    np.testing.assert_allclose(np.asarray(got.rnorm_history),
                               np.asarray(base.rnorm_history), rtol=1e-10)


def test_pmg_beats_cheb4_iterations(x64, rng):
    """The headline: tolerance-driven pmg-PCG needs at most half the
    iterations of cheb4 on a shared (small) case.  The paper-scale
    E=1024/n=10 version of this check is benchmarks/pmg_smoke.py."""
    case = _case(n=7, grid=(2, 2, 4))
    f = _masked_rhs(rng, case)
    r0 = float(jnp.sqrt(jnp.abs(jnp.sum(f * case.c * f))))
    tol = 1e-8 * r0
    kw = dict(D=case.D, g=case.g, grid=case.grid, tol=tol, max_iter=200,
              mask=case.mask, c=case.c, interpret=True)
    chb = pc.cg_fused_tol(f, precond=case.precond_spec("cheb4"), **kw)
    pmgr = pc.cg_fused_tol(f, precond=case.precond_spec("pmg"), **kw)
    assert float(pmgr.rnorm) <= float(chb.rnorm) * 10
    assert int(pmgr.iters) <= int(chb.iters) // 2, (
        f"pmg {int(pmgr.iters)} vs cheb4 {int(chb.iters)}")


def test_case_solve_routes_pmg(x64):
    """precond='pmg' flows through the registry (v2 fixed-iter + tol) and
    the reference path on non-fused ax_impls."""
    case = _case()
    res, _ = case.solve_manufactured(niter=6, precond="pmg")
    assert res.precond == "pmg" and res.pipeline == "fused_v2"
    ref_case = NekboneCase(n=5, grid=GRID, dtype=jnp.float64,
                           ax_impl="fused")
    ref, _ = ref_case.solve_manufactured(niter=6, precond="pmg")
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(ref.x),
                               rtol=0, atol=1e-10)


# ---------------------------------------------------------------------------
# satellite 1: b>1 on an s-step case — explicit, warned fallback
# ---------------------------------------------------------------------------

def test_sstep_batched_falls_back_to_block_with_warning(x64, rng):
    from repro.core import solvers as solvers_mod

    case = NekboneCase(n=5, grid=GRID, dtype=jnp.float64,
                       ax_impl="pallas_sstep_v3")
    f1 = _masked_rhs(rng, case)
    f = jnp.stack([f1, 2.0 * f1])
    solvers_mod._SSTEP_BLOCK_WARNED = False
    with pytest.warns(UserWarning, match="no batched s-step kernel"):
        res = case.solve(f, niter=4)
    assert res.pipeline == "fused_v2_rhs2"
    assert res.x.shape == f.shape
    # one-time: a second batched solve stays silent
    import warnings as _warnings

    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        case.solve(f, niter=4)
