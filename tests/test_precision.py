"""Mixed-precision fused CG (DESIGN.md §7): policy resolution, storage
parity against the fp64 oracle, kernel accumulation dtypes, and the
iterative-refinement floor — including the paper's E=1024, n=10 acceptance
case."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import cg as cg_mod
from repro.core.cg_fused import (cg_fused_fixed_iters, cg_fused_v2_fixed_iters,
                                 cg_ir_fixed_iters)
from repro.core.nekbone import NekboneCase
from repro.core.precision import POLICIES, PrecisionPolicy, resolve_policy


# ---------------------------------------------------------------------------
# policy resolution
# ---------------------------------------------------------------------------

def test_policy_registry_and_resolution():
    assert POLICIES["f64"].storage_dtype == jnp.dtype("float64")
    assert POLICIES["bf16"].storage_dtype == jnp.dtype(jnp.bfloat16)
    assert POLICIES["bf16"].accum_dtype == jnp.dtype("float32")
    assert POLICIES["bf16"].itemsize == 2
    assert not POLICIES["bf16"].refine and POLICIES["bf16_ir"].refine
    # the refined bf16 policy widens x and the operator data, NOT r/p/w
    assert POLICIES["bf16_ir"].x_storage_dtype == jnp.dtype("float32")
    assert POLICIES["bf16_ir"].op_storage_dtype == jnp.dtype("float32")
    assert POLICIES["bf16_ir"].storage_dtype == jnp.dtype(jnp.bfloat16)
    # unrefined policies keep x/op at the storage dtype
    assert POLICIES["bf16"].x_storage_dtype == jnp.dtype(jnp.bfloat16)
    assert POLICIES["f32"].op_storage_dtype == jnp.dtype("float32")

    # name, instance, and dtype-inference paths
    assert resolve_policy("bf16_ir") is POLICIES["bf16_ir"]
    pol = PrecisionPolicy("custom", "float32", "float64")
    assert resolve_policy(pol) is pol
    assert resolve_policy(None, jnp.float32) is POLICIES["f32"]
    assert resolve_policy(None, jnp.bfloat16) is POLICIES["bf16"]
    with pytest.raises(ValueError):
        resolve_policy("fp8")
    with pytest.raises(ValueError):
        resolve_policy(None)

    # eps is the storage dtype's machine epsilon (parity tolerance scale):
    # 8-bit significand -> 2^-7, 24-bit -> 2^-23
    assert POLICIES["bf16"].eps == pytest.approx(2.0 ** -7)
    assert POLICIES["f32"].eps == pytest.approx(2.0 ** -23)


# ---------------------------------------------------------------------------
# storage parity: low-precision pipelines track the fp64 oracle to a
# tolerance derived from the storage dtype's eps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", ["f32", "bf16"])
@pytest.mark.parametrize("variant", ["v1", "v2"])
def test_storage_parity_vs_fp64_reference(x64, precision, variant):
    """bf16/f32-storage residual histories track the fp64 reference.

    Tolerance: 64 * storage-eps relative, compared only while the
    reference is above its own comparison floor (once fp64 CG converges to
    round-off on a tiny case, relative comparison to a stalled
    low-precision run is meaningless).
    """
    case = NekboneCase(n=5, grid=(2, 3, 2), dtype=jnp.float64)
    _, f = case.manufactured()
    niter = 8
    ref = np.asarray(cg_mod.cg_fixed_iters(case.ax_full, f, niter=niter,
                                           dot=case.dot()).rnorm_history)

    kw = dict(D=case.D, g=case.g, grid=case.grid, niter=niter,
              precision=precision)
    if variant == "v1":
        res = cg_fused_fixed_iters(f, mask=case.mask, c=case.c, **kw)
    else:
        res = cg_fused_v2_fixed_iters(f, **kw)

    pol = POLICIES[precision]
    assert res.x.dtype == pol.storage_dtype
    # the history lives in the accumulation dtype, not storage
    assert res.rnorm_history.dtype == pol.accum_dtype

    got = np.asarray(res.rnorm_history, np.float64)
    tol = 64.0 * pol.eps
    alive = ref / ref[0] > tol          # reference above the storage floor
    rel = np.abs(got[alive] - ref[alive]) / ref[alive]
    assert rel.max() <= tol, (precision, variant, rel.max(), tol)


def test_bf16_storage_is_deterministically_rounded(x64):
    """The v2 kernels round p/r through storage before partials: two runs
    must agree bitwise, and the returned fields must be genuine bf16."""
    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float64)
    _, f = case.manufactured()
    a = cg_fused_v2_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                                niter=5, precision="bf16")
    b = cg_fused_v2_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                                niter=5, precision="bf16")
    assert a.x.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(a.x, np.float32),
                                  np.asarray(b.x, np.float32))


# ---------------------------------------------------------------------------
# kernel accumulation dtype (the acc side of the policy)
# ---------------------------------------------------------------------------

def test_kernel_partials_in_accum_dtype(rng, x64):
    """bf16 operands with explicit acc dtypes: partials come back in acc,
    fields in storage, and wider accumulation is at least as accurate."""
    from repro.core.sem import derivative_matrix
    from repro.kernels import nekbone_ax as _ax

    E, n = 4, 4
    D64 = np.asarray(derivative_matrix(n))
    u64 = rng.normal(size=(E, n ** 3))
    g64 = rng.normal(size=(E, 6, n ** 3))

    u = jnp.asarray(u64, jnp.bfloat16)
    D = jnp.asarray(D64, jnp.bfloat16)
    g = jnp.asarray(g64, jnp.bfloat16)
    mask = jnp.ones((E, n ** 3), jnp.bfloat16)

    outs = {}
    for acc in ("float32", "float64"):
        w, pap = _ax.nekbone_ax_pap_pallas(u, D, D.T, g, mask, n=n,
                                           block_e=2, interpret=True,
                                           acc_dtype=acc)
        assert w.dtype == jnp.bfloat16
        assert pap.dtype == jnp.dtype(acc)
        outs[acc] = float(jnp.sum(pap))

    # same bf16 operands, so both accumulations see identical inputs; the
    # f64 reference on the *rounded* operands is the exact answer.
    from repro.core.ax import ax_local_fused
    u_ref = jnp.asarray(np.asarray(u, np.float64)).reshape(E, n, n, n)
    g_ref = jnp.asarray(np.asarray(g, np.float64)).reshape(E, 6, n, n, n)
    D_ref = jnp.asarray(np.asarray(D, np.float64))
    w_ref = ax_local_fused(u_ref, D_ref, g_ref)
    pap_ref = float(jnp.sum(u_ref.reshape(E, n ** 3)
                            * w_ref.reshape(E, n ** 3)))
    err32 = abs(outs["float32"] - pap_ref)
    err64 = abs(outs["float64"] - pap_ref)
    assert err64 <= err32 + 1e-12 * abs(pap_ref)
    assert err32 <= 1e-5 * abs(pap_ref)      # f32 accumulation, not bf16


# ---------------------------------------------------------------------------
# iterative refinement: bf16-priced streams, fp64-class floors
# ---------------------------------------------------------------------------

def test_ir_recovers_f64_floor_small(x64):
    """bf16_ir on a small case: outer residuals reach the fp64 floor of
    the same fixed-iteration budget, and the refined solution is f64."""
    case = NekboneCase(n=6, grid=(2, 2, 4), dtype=jnp.float64)
    _, f = case.manufactured()
    niter = 40
    ref = cg_mod.cg_fixed_iters(case.ax_full, f, niter=niter,
                                dot=case.dot())
    rel_ref = float(ref.rnorm / ref.rnorm_history[0])

    ir = cg_ir_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                           niter=niter, precision="bf16_ir")
    assert ir.x.dtype == jnp.float64    # refined solution in b's precision
    hist = np.asarray(ir.rnorm_history, np.float64)
    assert np.all(np.isfinite(hist))
    rel_ir = float(ir.rnorm / ir.rnorm_history[0])
    assert rel_ir <= rel_ref, (rel_ir, rel_ref)


def test_ir_monotone_outer_residuals(x64):
    """Each refinement sweep must not increase the true residual (the
    inner solves run full-length, past the CG residual transient)."""
    case = NekboneCase(n=5, grid=(2, 2, 2), dtype=jnp.float64)
    _, f = case.manufactured()
    ir = cg_ir_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                           niter=30, precision="bf16_ir", outer_iters=3)
    hist = np.asarray(ir.rnorm_history, np.float64)
    assert hist.shape == (4,)
    assert np.all(hist[1:] <= hist[:-1] * 1.05), hist


def test_ir_f32_policy_reaches_f32_class_floor(x64):
    case = NekboneCase(n=5, grid=(2, 2, 2), dtype=jnp.float64)
    _, f = case.manufactured()
    ir = cg_ir_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                           niter=30, precision="f32_ir")
    rel = float(ir.rnorm / ir.rnorm_history[0])
    assert rel < 1e-8                    # two f32 sweeps compound past 1e-8
    assert int(ir.iters) == 60


def test_ir_v1_variant(x64):
    """The refinement driver also runs over the v1 (flat-block) pipeline."""
    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float64)
    _, f = case.manufactured()
    ir = cg_ir_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                           niter=20, precision="bf16_ir", outer_iters=2,
                           variant="v1", mask=case.mask, c=case.c)
    hist = np.asarray(ir.rnorm_history, np.float64)
    assert hist[-1] < hist[0]


# the acceptance case (ISSUE 3): paper protocol size, interpret mode
def test_ir_paper_case_matches_f64_floor(x64):
    """bf16_ir matches the fp64 ``cg_fixed_iters`` 100-iteration residual
    floor on the paper's E=1024, n=10 case (§V protocol), interpret mode.

    bf16 storage alone stalls ~50x above the fp64 floor on this case; the
    refinement loop's five full-length sweeps recover it (DESIGN.md §7).
    """
    case = NekboneCase(n=10, grid=(8, 8, 16), dtype=jnp.float64)
    _, f = case.manufactured()
    ref = cg_mod.cg_fixed_iters(case.ax_full, f, niter=100, dot=case.dot())
    rel_ref = float(ref.rnorm / ref.rnorm_history[0])

    ir = cg_ir_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                           niter=100, precision="bf16_ir")
    rel_ir = float(ir.rnorm / ir.rnorm_history[0])
    assert rel_ir <= rel_ref, (rel_ir, rel_ref)


# ---------------------------------------------------------------------------
# NekboneCase precision field
# ---------------------------------------------------------------------------

def test_case_precision_field_storage_policies():
    case = NekboneCase(n=4, grid=(2, 2, 2), precision="bf16",
                       ax_impl="pallas_fused_cg_v2")
    assert jnp.dtype(case.dtype) == jnp.dtype(jnp.bfloat16)
    res, _ = case.solve_manufactured(niter=4)
    assert res.x.dtype == jnp.bfloat16
    assert np.all(np.isfinite(np.asarray(res.rnorm_history, np.float64)))


def test_case_precision_field_refined(x64):
    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float64,
                       precision="bf16_ir", ax_impl="pallas_fused_cg_v2")
    assert jnp.dtype(case.dtype) == jnp.dtype(jnp.float64)  # outer precision
    res, u_ex = case.solve_manufactured(niter=20)
    assert res.x.dtype == jnp.float64
    hist = np.asarray(res.rnorm_history, np.float64)
    assert hist[-1] < hist[0]


def test_config_precision_field():
    from repro.configs.nekbone import paper_case

    cfg = paper_case(64, precision="bf16_ir")
    assert cfg.precision == "bf16_ir"
    case = cfg.make_case(n=4, grid=(2, 2, 2))
    assert case.precision == "bf16_ir"
