"""Preconditioning subsystem (core/precond.py, DESIGN.md §9).

fp64 interpret-mode parity of the fused Jacobi / Chebyshev PCG pipelines
against the reference preconditioned solvers, the tolerance-driven
drivers' prefix/padding semantics, the Lanczos interval estimator, the
Chebyshev scalar algebra, and the case/config wiring — plus the ISSUE-5
acceptance case (Chebyshev-PCG(4) reaches 1e-8 on the paper's
E=1024/n=10 grid inside the 100-iteration protocol the unpreconditioned
pipeline cannot).
"""
import numpy as np
import pytest

import jax.numpy as jnp

import repro.core.cg as cg_mod
import repro.core.gs as gs_mod
from repro.core import precond as pc
from repro.core.cg_fused import cg_fused_v2_fixed_iters
from repro.core.nekbone import NekboneCase

# fp64 parity budget: round-off through the different partial-sum
# associations plus (Jacobi) the z-carried reformulation's reciprocal
# reconstruction — both eps-level per iteration (DESIGN.md §9.2).
RTOL = 1e-10


def _random_rhs(case, seed=0):
    """A random assembled ("continuous") masked right-hand side."""
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(size=case.mask.shape), case.dtype)
    return gs_mod.ds_sum_local(u, case.grid) * case.mask


def _assert_parity(ref, fused, rtol=RTOL):
    h_ref = np.asarray(ref.rnorm_history)
    h_fus = np.asarray(fused.rnorm_history)
    assert h_fus.shape == h_ref.shape
    np.testing.assert_allclose(h_fus, h_ref, rtol=0, atol=rtol * h_ref[0])
    xs = np.abs(np.asarray(ref.x)).max() + 1e-300
    np.testing.assert_allclose(np.asarray(fused.x), np.asarray(ref.x),
                               atol=rtol * xs)


# ---------------------------------------------------------------------------
# operator diagonal
# ---------------------------------------------------------------------------

def test_operator_diagonal_matches_assembled_operator(x64):
    """diag entries equal (A e_u)|_u for continuous unit indicators e_u."""
    case = NekboneCase(n=3, grid=(2, 2, 2), dtype=jnp.float64)
    diag = np.asarray(case.operator_diagonal())
    mask = np.asarray(case.mask)
    mult = np.asarray(case.mult)
    rng = np.random.default_rng(3)
    flat_idx = rng.choice(mask.size, size=12, replace=False)
    for fi in flat_idx:
        idx = np.unravel_index(fi, mask.shape)
        if mask[idx] == 0:
            assert diag[idx] == 1.0       # masked rows: identity-like
            continue
        # continuous indicator: 1 on every coincident copy of the node —
        # assembling a single-copy impulse marks exactly those copies.
        e = np.zeros(mask.shape)
        e[idx] = 1.0
        spread = np.asarray(gs_mod.ds_sum_local(jnp.asarray(e), case.grid))
        e = (spread != 0).astype(np.float64)
        got = float(np.asarray(case.ax_full(jnp.asarray(e)))[idx])
        np.testing.assert_allclose(got, diag[idx], rtol=1e-12)
    assert mult.min() >= 1.0              # sanity: mesh fields loaded


# ---------------------------------------------------------------------------
# Chebyshev scalars
# ---------------------------------------------------------------------------

def test_cheb_scalars_error_polynomial_bound():
    """The recurrence realizes the Chebyshev minimax error on [a, b].

    Emulating the kernel recurrence on scalars (A = lambda) must give
    ``z = q_k(lambda)`` with ``|1 - lambda q_k|`` <= 1/T_k(sigma1) on the
    interval and ``q_k > 0`` there (the SPD property PCG rests on).
    """
    a, b = 0.03, 2.7
    for k in (1, 2, 4, 6):
        coef = pc.cheb_scalars(k, a, b)
        sigma1 = (b + a) / (b - a)
        bound = 1.0 / np.cosh(k * np.arccosh(sigma1))
        lam = np.linspace(a, b, 101)
        d = coef[0, 0] * np.ones_like(lam)
        z = d.copy()
        res = np.ones_like(lam)
        for i in range(1, k + 1):
            res = res - lam * d
            d = coef[i, 0] * d + coef[i, 1] * res
            z = z + d
        err = np.abs(1.0 - lam * z)
        assert err.max() <= bound * (1 + 1e-9), (k, err.max(), bound)
        assert z.min() > 0.0, f"q_{k} not positive on the interval"


def test_cheb_scalars_rejects_bad_interval():
    with pytest.raises(ValueError, match="lmin < lmax"):
        pc.cheb_scalars(2, 1.0, 0.5)
    with pytest.raises(ValueError, match="order"):
        pc.cheb_scalars(0, 0.1, 1.0)


# ---------------------------------------------------------------------------
# Lanczos interval estimate
# ---------------------------------------------------------------------------

def test_estimate_interval_brackets_rayleigh_quotients(x64):
    case = NekboneCase(n=4, grid=(2, 2, 3), dtype=jnp.float64)
    lmin, lmax = pc.estimate_interval(case.D, case.g, case.grid, case.mask,
                                      case.c)
    assert 0.0 < lmin < lmax
    rng = np.random.default_rng(7)
    dot = case.dot()
    for seed in range(5):
        v = _random_rhs(case, seed=rng.integers(1 << 30))
        num = float(dot(v, case.ax_full(v)))
        den = float(dot(v, v))
        rayleigh = num / den
        # lmax is inflated 5% above the top Ritz value (the SPD-critical
        # end), lmin deflated 10% below the bottom one — any Rayleigh
        # quotient of a continuous masked vector must fall inside.
        assert lmin * 0.999 <= rayleigh <= lmax * 1.001, (
            rayleigh, lmin, lmax)
    # consistency with the one-sided power-iteration estimate theta ~ ||A||
    from repro.core.cg_sstep import estimate_theta

    theta = estimate_theta(case.D, case.g, case.grid, case.mask)
    assert lmax >= 0.8 * theta


# ---------------------------------------------------------------------------
# fused Jacobi-PCG parity (the ISSUE-5 'randomized grids' acceptance)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,grid,seed", [
    (4, (2, 2, 2), 0),
    (5, (2, 3, 4), 1),
    (3, (1, 2, 4), 2),
    (6, (3, 1, 2), 3),
])
def test_pcg_jacobi_fused_matches_reference_fp64(x64, n, grid, seed):
    case = NekboneCase(n=n, grid=grid, dtype=jnp.float64)
    f = _random_rhs(case, seed=seed)
    diag = case.operator_diagonal()
    ref = cg_mod.cg_fixed_iters(
        case.ax_full, f, niter=10, dot=case.dot(),
        precond=cg_mod.jacobi_preconditioner(diag))
    fused = pc.pcg_fused_v2_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=10,
        precond=pc.JacobiPrecond(invdiag=1.0 / diag), mask=case.mask,
        c=case.c, interpret=True)
    _assert_parity(ref, fused)


@pytest.mark.parametrize("sz", [1, 2, 4])
def test_pcg_jacobi_invariant_to_slab_split(x64, sz):
    case = NekboneCase(n=4, grid=(2, 2, 4), dtype=jnp.float64)
    f = _random_rhs(case, seed=4)
    diag = case.operator_diagonal()
    ref = cg_mod.cg_fixed_iters(
        case.ax_full, f, niter=6, dot=case.dot(),
        precond=cg_mod.jacobi_preconditioner(diag))
    fused = pc.pcg_fused_v2_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=6,
        precond=pc.JacobiPrecond(invdiag=1.0 / diag), sz=sz,
        interpret=True)
    _assert_parity(ref, fused)


# ---------------------------------------------------------------------------
# fused Chebyshev-PCG parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2, 4])
def test_pcg_cheb_fused_matches_reference_fp64(x64, k):
    case = NekboneCase(n=5, grid=(2, 3, 4), dtype=jnp.float64)
    f = _random_rhs(case, seed=5)
    lmin, lmax = pc.estimate_interval(case.D, case.g, case.grid, case.mask,
                                      case.c)
    ref = cg_mod.cg_fixed_iters(
        case.ax_full, f, niter=10, dot=case.dot(),
        precond=pc.chebyshev_preconditioner(case.ax_full, k, lmin, lmax))
    fused = pc.pcg_fused_v2_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=10,
        precond=pc.ChebyshevPrecond(k=k, lmin=lmin, lmax=lmax),
        mask=case.mask, c=case.c, interpret=True)
    _assert_parity(ref, fused)


@pytest.mark.parametrize("cheb_sz", [1, 2, 4])
def test_pcg_cheb_invariant_to_slab_split(x64, cheb_sz):
    """The cheb kernel's halo'd slab split changes only associations."""
    case = NekboneCase(n=4, grid=(2, 2, 4), dtype=jnp.float64)
    f = _random_rhs(case, seed=6)
    spec = pc.ChebyshevPrecond(k=2, lmin=0.05, lmax=3.0)
    base = pc.pcg_fused_v2_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=6, precond=spec,
        cheb_sz=4, interpret=True)
    other = pc.pcg_fused_v2_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=6, precond=spec,
        cheb_sz=cheb_sz, interpret=True)
    _assert_parity(base, other, rtol=1e-12)


# ---------------------------------------------------------------------------
# tolerance-driven fused solves
# ---------------------------------------------------------------------------

def test_cg_fused_tol_prefix_padding_and_early_exit(x64):
    case = NekboneCase(n=5, grid=(2, 3, 4), dtype=jnp.float64)
    _, f = case.manufactured()
    fixed = cg_fused_v2_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                                    niter=20, mask=case.mask, c=case.c,
                                    interpret=True)
    h_fix = np.asarray(fixed.rnorm_history)
    # target the second-to-last entry: the first crossing is guaranteed
    # to sit strictly inside (0, 20), so the while_loop genuinely exits
    # early (tol at the history *minimum* would run the full budget).
    tol = float(h_fix[-2]) * (1.0 + 1e-12)
    res = pc.cg_fused_tol(f, D=case.D, g=case.g, grid=case.grid, tol=tol,
                          max_iter=20, mask=case.mask, c=case.c,
                          interpret=True)
    it = int(res.iters)
    h = np.asarray(res.rnorm_history)
    assert 0 < it < 20                            # a real early exit
    assert h.shape == (21,)                       # padded to max_iter + 1
    # the trajectory is the fixed-iteration one's prefix, by construction
    np.testing.assert_array_equal(h[:it + 1], h_fix[:it + 1])
    assert np.isnan(h[it + 1:]).all()             # untouched entries: NaN
    assert float(res.rnorm) <= tol
    assert float(res.rnorm) == h[it]


def test_cg_fused_tol_max_iter_cap(x64):
    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float64)
    _, f = case.manufactured()
    res = pc.cg_fused_tol(f, D=case.D, g=case.g, grid=case.grid, tol=0.0,
                          max_iter=7, mask=case.mask, c=case.c,
                          interpret=True)
    assert int(res.iters) == 7
    assert np.isfinite(np.asarray(res.rnorm_history)).all()


@pytest.mark.parametrize("precond", ["jacobi", "cheb"])
def test_cg_fused_tol_pcg_prefix_of_fixed(x64, precond):
    """The PCG tol drivers share their bodies with the fixed-iter ones."""
    case = NekboneCase(n=4, grid=(2, 2, 4), dtype=jnp.float64)
    _, f = case.manufactured()
    spec = (pc.JacobiPrecond(invdiag=1.0 / case.operator_diagonal())
            if precond == "jacobi"
            else pc.ChebyshevPrecond(k=2, lmin=0.05, lmax=3.0))
    fixed = pc.pcg_fused_v2_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=15, precond=spec,
        mask=case.mask, c=case.c, interpret=True)
    h_fix = np.asarray(fixed.rnorm_history)
    # stop on the rtz measure mid-trajectory: pick an rcr level the run
    # is known to pass through (rtz and rcr track each other within the
    # preconditioner's spectral scale, so the stop lands inside the run)
    res = pc.cg_fused_tol(f, D=case.D, g=case.g, grid=case.grid,
                          tol=float(h_fix[-2]), max_iter=15, precond=spec,
                          mask=case.mask, c=case.c, interpret=True)
    it = int(res.iters)
    h = np.asarray(res.rnorm_history)
    assert 0 < it <= 15
    np.testing.assert_array_equal(h[:it + 1], h_fix[:it + 1])
    if it < 15:
        assert np.isnan(h[it + 1:]).all()


def test_pcg_reduces_iterations_to_threshold(x64):
    """Jacobi and Chebyshev cross a residual threshold before plain CG."""
    case = NekboneCase(n=5, grid=(2, 3, 4), dtype=jnp.float64)
    _, f = case.manufactured()
    niter = 40
    plain = cg_fused_v2_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                                    niter=niter, mask=case.mask, c=case.c,
                                    interpret=True)
    h0 = float(plain.rnorm_history[0])
    thresh = 1e-6 * h0

    def crossing(res):
        h = np.asarray(res.rnorm_history)
        idx = np.nonzero(h <= thresh)[0]
        return int(idx[0]) if idx.size else niter + 1

    jac = pc.pcg_fused_v2_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=niter,
        precond=pc.JacobiPrecond(invdiag=1.0 / case.operator_diagonal()),
        mask=case.mask, c=case.c, interpret=True)
    lmin, lmax = pc.estimate_interval(case.D, case.g, case.grid, case.mask,
                                      case.c)
    chb = pc.pcg_fused_v2_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=niter,
        precond=pc.ChebyshevPrecond(k=4, lmin=lmin, lmax=lmax),
        mask=case.mask, c=case.c, interpret=True)
    assert crossing(jac) < crossing(plain)
    assert crossing(chb) < crossing(jac)


# ---------------------------------------------------------------------------
# s-step tolerance stopping (DESIGN.md §9.4)
# ---------------------------------------------------------------------------

def test_cg_sstep_tol_prefix_and_iteration_granularity(x64):
    from repro.core.cg_sstep import cg_sstep_fixed_iters, estimate_theta

    case = NekboneCase(n=5, grid=(2, 2, 4), dtype=jnp.float64)
    _, f = case.manufactured()
    theta = estimate_theta(case.D, case.g, case.grid, case.mask)
    fixed = cg_sstep_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                                 niter=20, s=4, mask=case.mask, c=case.c,
                                 theta=theta, interpret=True)
    h_fix = np.asarray(fixed.rnorm_history)
    # a mid-cycle target (index 10 of s=4 cycles) exercises the
    # recurrence re-run: the driver must stop at iteration granularity,
    # not cycle granularity.
    tol = float(h_fix[10]) * (1.0 + 1e-9)
    res = cg_sstep_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                               niter=20, s=4, mask=case.mask, c=case.c,
                               theta=theta, tol=tol, interpret=True)
    it = int(res.iters)
    h = np.asarray(res.rnorm_history)
    assert it == 10
    assert h.shape == (it + 1,)
    np.testing.assert_allclose(h[:it], h_fix[:it], rtol=1e-12)
    assert float(res.rnorm) <= tol


def test_cg_sstep_tol_through_case(x64):
    cfg_case = NekboneCase(n=4, grid=(2, 2, 4), dtype=jnp.float64,
                           ax_impl="pallas_sstep_v3")
    res, _ = cfg_case.solve_manufactured(tol=1e-6, max_iter=100)
    assert 0 < int(res.iters) < 100
    assert float(res.rnorm) <= 1e-6


# ---------------------------------------------------------------------------
# precision policies compose
# ---------------------------------------------------------------------------

def test_pcg_jacobi_f32_converges():
    case = NekboneCase(n=5, grid=(2, 2, 4), dtype=jnp.float32)
    _, f = case.manufactured()
    res = pc.pcg_fused_v2_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=30,
        precond=pc.JacobiPrecond(invdiag=1.0 / case.operator_diagonal()),
        mask=case.mask, c=case.c, interpret=True, precision="f32")
    h = np.asarray(res.rnorm_history)
    assert np.isfinite(h).all()
    assert h[-1] < h[0] * 1e-3


def test_pcg_jacobi_bf16_runs(x64):
    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float64)
    _, f = case.manufactured()
    res = pc.pcg_fused_v2_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=5,
        precond=pc.JacobiPrecond(invdiag=1.0 / case.operator_diagonal()),
        mask=case.mask, c=case.c, interpret=True, precision="bf16")
    assert res.x.dtype == jnp.bfloat16
    h = np.asarray(res.rnorm_history, np.float64)
    assert np.isfinite(h).all()
    assert h[-1] < h[0]


# ---------------------------------------------------------------------------
# case / config wiring
# ---------------------------------------------------------------------------

def test_case_and_config_precond_wiring(x64):
    from repro.configs.nekbone import NekboneConfig

    cfg = NekboneConfig(name="t", n=4, grid=(2, 2, 4), dtype="float64",
                        ax_impl="pallas_fused_cg_v2", precond="jacobi")
    case = cfg.make_case()
    assert case.precond == "jacobi"
    res, _ = case.solve_manufactured(niter=8)
    ref = cg_mod.cg_fixed_iters(
        case.ax_full, case.manufactured()[1], niter=8, dot=case.dot(),
        precond=cg_mod.jacobi_preconditioner(case.operator_diagonal()))
    _assert_parity(ref, res)
    # unpreconditioned comparison point: a case without a default
    # (the boolean override spelling was removed — see
    # test_case_solve_precond_booleans_removed)
    plain, _ = cfg.make_case(precond=None).solve_manufactured(niter=8)
    ref_plain = cg_mod.cg_fixed_iters(case.ax_full, case.manufactured()[1],
                                      niter=8, dot=case.dot())
    _assert_parity(ref_plain, plain)
    # cheb_k flows from the config into the spec
    cfg_c = NekboneConfig(name="t2", n=4, grid=(2, 2, 4), dtype="float64",
                          ax_impl="pallas_fused_cg_v2", precond="cheb",
                          cheb_k=2)
    case_c = cfg_c.make_case()
    assert case_c.precond_spec().k == 2


def test_case_solve_precond_booleans_removed(x64):
    """The boolean compat shim is gone: both spellings raise TypeError."""
    case = NekboneCase(n=5, grid=(2, 2, 2), dtype=jnp.float64)
    with pytest.raises(TypeError, match="removed"):
        case.solve_manufactured(tol=1e-8, max_iter=400, precond=True)
    with pytest.raises(TypeError, match="removed"):
        case.solve_manufactured(tol=1e-8, max_iter=400, precond=False)


def test_case_tol_solve_routes_to_fused_v2(x64):
    """niter=None v2 solves run the fused while_loop driver, not XLA cg."""
    case = NekboneCase(n=4, grid=(2, 2, 4), dtype=jnp.float64,
                       ax_impl="pallas_fused_cg_v2")
    res, _ = case.solve_manufactured(tol=1e-6, max_iter=100)
    assert 0 < int(res.iters) < 100
    assert float(res.rnorm) <= 1e-6
    assert res.rnorm_history.shape == (101,)


def test_make_preconditioner_names():
    case = NekboneCase(n=4, grid=(2, 2, 2), dtype=jnp.float32)
    jac = pc.make_preconditioner("jacobi", D=case.D, g=case.g,
                                 grid=case.grid, mask=case.mask, c=case.c)
    assert isinstance(jac, pc.JacobiPrecond)
    chb = pc.make_preconditioner("cheb2", D=case.D, g=case.g,
                                 grid=case.grid, mask=case.mask, c=case.c)
    assert isinstance(chb, pc.ChebyshevPrecond) and chb.k == 2
    with pytest.raises(ValueError, match="unknown preconditioner"):
        pc.make_preconditioner("ilu", D=case.D, g=case.g, grid=case.grid,
                               mask=case.mask)


# ---------------------------------------------------------------------------
# the ISSUE-5 acceptance case: paper grid, solve-to-1e-8
# ---------------------------------------------------------------------------

def test_cheb_pcg_paper_case_beats_unpreconditioned(x64):
    """Chebyshev-PCG(4) reaches rnorm <= 1e-8 on the paper's E=1024/n=10
    case in measurably fewer iterations than unpreconditioned v2 — which
    cannot reach it within the paper's 100-iteration protocol at all
    (it stalls ~2.4e-6 absolute; ISSUE-5 acceptance).
    """
    case = NekboneCase(n=10, grid=(8, 8, 16), dtype=jnp.float64)
    _, f = case.manufactured()
    plain = cg_fused_v2_fixed_iters(f, D=case.D, g=case.g, grid=case.grid,
                                    niter=100, mask=case.mask, c=case.c,
                                    interpret=True)
    h_plain = np.asarray(plain.rnorm_history)
    assert h_plain.min() > 1e-8, "plain v2 unexpectedly reached 1e-8"

    lmin, lmax = pc.estimate_interval(case.D, case.g, case.grid, case.mask,
                                      case.c)
    # cheb_sz=16 (one z-block): interpret-mode halo redundancy is the
    # dominant wall-clock cost, and the split only changes associations
    # (pinned by test_pcg_cheb_invariant_to_slab_split).
    chb = pc.pcg_fused_v2_fixed_iters(
        f, D=case.D, g=case.g, grid=case.grid, niter=34,
        precond=pc.ChebyshevPrecond(k=4, lmin=lmin, lmax=lmax),
        mask=case.mask, c=case.c, cheb_sz=16, interpret=True)
    h_chb = np.asarray(chb.rnorm_history)
    crossed = np.nonzero(h_chb <= 1e-8)[0]
    assert crossed.size, "Chebyshev-PCG(4) did not reach 1e-8 in 34 iters"
    assert int(crossed[0]) < 100, "not fewer iterations than plain's >100"
