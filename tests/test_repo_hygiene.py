"""Tracked-file hygiene: generated bench output and bytecode must never
enter the index (the same check CI runs as a shell step — a tracked
``benchmarks/out/BENCH_*.json`` would make the regression gate diff a
file against itself)."""
import pathlib
import shutil
import subprocess

import pytest

REPO = pathlib.Path(__file__).parent.parent
FORBIDDEN = ("benchmarks/out/", "__pycache__/")


def _tracked_files():
    if shutil.which("git") is None or not (REPO / ".git").exists():
        pytest.skip("not a git checkout")
    proc = subprocess.run(["git", "ls-files"], cwd=REPO,
                          capture_output=True, text=True)
    if proc.returncode != 0:
        pytest.skip(f"git ls-files failed: {proc.stderr.strip()}")
    return proc.stdout.splitlines()


def test_no_generated_files_tracked():
    bad = [f for f in _tracked_files()
           if any(pat in f + "/" or f"/{pat}" in f or f.startswith(pat)
                  for pat in FORBIDDEN)]
    assert bad == [], f"generated files tracked in git: {bad}"


def test_baseline_is_tracked():
    """The flip side: the gate's baseline must BE in the index, or the CI
    leg silently compares against nothing."""
    assert "benchmarks/baseline/BENCH_baseline.json" in _tracked_files()
