"""SEM 1-D operator properties: quadrature exactness, spectral derivative."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sem import SEMOperators, derivative_matrix, gll_points_weights


@pytest.mark.parametrize("n", [2, 3, 4, 7, 10, 12, 16])
def test_gll_basics(n):
    z, w = gll_points_weights(n)
    assert z[0] == -1.0 and z[-1] == 1.0
    assert np.all(np.diff(z) > 0), "nodes strictly increasing"
    assert abs(w.sum() - 2.0) < 1e-13, "weights integrate 1 exactly"
    assert np.allclose(z, -z[::-1]) and np.allclose(w, w[::-1]), "symmetry"


@settings(deadline=None, max_examples=30)
@given(n=st.integers(2, 14), deg=st.integers(0, 25))
def test_quadrature_exactness(n, deg):
    """GLL with n points integrates monomials exactly up to degree 2n-3."""
    if deg > 2 * n - 3:
        return
    z, w = gll_points_weights(n)
    got = np.sum(w * z ** deg)
    exact = 0.0 if deg % 2 else 2.0 / (deg + 1)
    assert abs(got - exact) < 1e-11


@settings(deadline=None, max_examples=30)
@given(n=st.integers(2, 14), deg=st.integers(0, 13))
def test_derivative_exactness(n, deg):
    """D differentiates polynomials of degree <= n-1 exactly at the nodes."""
    if deg > n - 1:
        return
    z, _ = gll_points_weights(n)
    D = derivative_matrix(n)
    got = D @ (z ** deg)
    exact = deg * z ** (deg - 1) if deg > 0 else np.zeros_like(z)
    assert np.max(np.abs(got - exact)) < 1e-10 * max(1, n ** 2)


def test_derivative_row_sums_zero():
    """D @ const = 0 (derivative of a constant)."""
    for n in (2, 5, 10):
        D = derivative_matrix(n)
        assert np.max(np.abs(D.sum(axis=1))) < 1e-12


def test_sem_operators_bundle():
    ops = SEMOperators(10)
    assert ops.D.shape == (10, 10)
    assert ops.Dt.shape == (10, 10)
    assert np.allclose(ops.Dt, ops.D.T)
    assert ops.w3.shape == (10, 10, 10)
    assert abs(ops.w3.sum() - 8.0) < 1e-12        # integrates the unit cube
