"""Solver service (launch/solver_service.py): queue, buckets, dispatch.

The scheduling rules the serving layer promises (DESIGN.md §12):

* an empty queue drains to ``[]`` with zero dispatches;
* requests in different buckets — any difference in (grid, n, dtype,
  pipeline, precision, precond, stopping rule) — are NEVER co-scheduled;
* a bucket with more pending requests than ``max_b`` splits into
  ceil(k/max_b) dispatches, none exceeding ``max_b``;
* results return in submission order with correct request ids, and each
  answer equals the equivalent direct registry solve (parity through the
  batching layer).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs.nekbone import NekboneConfig
from repro.launch.solver_service import SolveRequest, SolverService


def _cfg(**over):
    base = dict(name="svc", n=4, grid=(2, 2, 2), dtype="float32",
                ax_impl="pallas_fused_cg_v2")
    base.update(over)
    return NekboneConfig(**base)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    case = cfg.make_case()
    _, f = case.manufactured()
    return cfg, case, f


def test_empty_queue_drains_empty():
    svc = SolverService(max_b=4)
    assert svc.drain() == []
    assert svc.dispatch_log == []
    assert svc.pending == 0


def test_mixed_buckets_never_co_scheduled(setup):
    cfg, case, f = setup
    cfg_pc = _cfg(precond="jacobi")
    cfg_tol = cfg                       # same case, different stopping rule
    svc = SolverService(max_b=8)
    ids_a = [svc.submit(SolveRequest(f=f, config=cfg, niter=4))
             for _ in range(2)]
    ids_b = [svc.submit(SolveRequest(f=f, config=cfg_pc, niter=4))]
    ids_c = [svc.submit(SolveRequest(f=f, config=cfg_tol, tol=1e-6))]
    results = svc.drain()
    assert [r.request_id for r in results] == ids_a + ids_b + ids_c
    assert len(svc.dispatch_log) == 3
    groups = [set(rids) for _, rids in svc.dispatch_log]
    assert set(ids_a) in groups
    assert set(ids_b) in groups
    assert set(ids_c) in groups
    # bucket keys of the three dispatches are pairwise distinct
    assert len({k for k, _ in svc.dispatch_log}) == 3


def test_bucket_overflow_splits(setup):
    cfg, case, f = setup
    svc = SolverService(max_b=3)
    ids = [svc.submit(SolveRequest(f=f, config=cfg, niter=3))
           for _ in range(7)]
    results = svc.drain()
    assert [r.request_id for r in results] == ids
    sizes = [len(rids) for _, rids in svc.dispatch_log]
    assert sizes == [3, 3, 1]           # ceil(7/3) chunks, order kept
    assert all(s <= svc.max_b for s in sizes)
    assert [r.batch_size for r in results] == [3, 3, 3, 3, 3, 3, 1]


def test_batched_answers_match_direct_solve(setup):
    cfg, case, f = setup
    svc = SolverService(max_b=4)
    rng = np.random.default_rng(1)
    fs = [f, jnp.asarray(rng.standard_normal(f.shape),
                         jnp.float32) * case.mask]
    ids = [svc.submit(SolveRequest(f=fi, config=cfg, niter=6))
           for fi in fs]
    results = svc.drain()
    assert len(svc.dispatch_log) == 1   # one bucket, one dispatch
    for r, fi in zip(results, fs):
        direct = case.solve(fi, niter=6)
        np.testing.assert_array_equal(np.asarray(r.x),
                                      np.asarray(direct.x))
        assert r.pipeline == "fused_v2_rhs2"
        assert int(r.iters_taken) == 6


def test_warm_start_populates_caches(setup, tmp_path, monkeypatch):
    cfg, case, f = setup
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    from repro.kernels import autotune

    autotune.clear_cache()
    svc = SolverService(max_b=2)
    warmed = svc.warm_start([cfg], batches=[1, 2], niter=1)
    assert warmed == 2
    # the case is cached for subsequent dispatches
    assert len(svc._cases) == 1
    autotune.clear_cache(disk=False)


def test_rejects_bad_max_b():
    with pytest.raises(ValueError, match="max_b"):
        SolverService(max_b=0)


# ---------------------------------------------------------------------------
# DispatchRecord: the tuple shim (ISSUE 10 satellite).  dispatch_log used
# to hold (bucket, request_ids) tuples; the dataclass must keep every
# legacy access pattern working — the asserts above (`!= []`, iteration
# unpacking, `{k for k, _ in ...}`, len) already exercise most of it,
# this pins the rest explicitly so a refactor cannot silently drop it.
# ---------------------------------------------------------------------------

def test_dispatch_record_tuple_shim():
    from repro.launch.solver_service import DispatchRecord

    rec = DispatchRecord(bucket=("bk",), request_ids=[1, 2, 3],
                         wall_us=5.0, pipeline="fused_v2_rhs3")
    # legacy tuple protocol: 2-tuple of (bucket, request_ids)
    assert len(rec) == 2
    assert rec[0] == ("bk",) and rec[1] == [1, 2, 3]
    bucket, rids = rec
    assert bucket == ("bk",) and rids == [1, 2, 3]
    assert rec == (("bk",), [1, 2, 3])
    assert rec != (("other",), [1, 2, 3])
    # equality against another record compares the same 2-tuple view
    assert rec == DispatchRecord(bucket=("bk",), request_ids=[1, 2, 3])
    # hashable (bucket keys land in sets in the tests above)
    assert isinstance(hash(rec), int)
    # batch_size fills from request_ids when not given
    assert rec.batch_size == 3


def test_dispatch_log_records_carry_telemetry(setup):
    cfg, case, f = setup
    from repro.launch.solver_service import DispatchRecord

    svc = SolverService(max_b=2)
    for _ in range(3):
        svc.submit(SolveRequest(f=f, config=cfg, niter=2))
    svc.drain()
    assert len(svc.dispatch_log) == 2
    for rec in svc.dispatch_log:
        assert isinstance(rec, DispatchRecord)
        assert rec.wall_us > 0
        assert rec.pipeline is not None
    assert [r.batch_size for r in svc.dispatch_log] == [2, 1]
    snap = svc.metrics.snapshot()
    assert snap["dispatches"] == 2
    assert snap["requests_served"] == 3
    assert snap["queue_high_water"] == 3
    assert snap["latency_ms"]["count"] == 2
