"""Optimizer, data pipeline, checkpoint manager, schedules."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import MemmapTokenReader, SyntheticLMStream
from repro.optim import adamw_init, adamw_update, cosine_schedule


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = adamw_init(params)
    target = jnp.asarray([1.0, 1.0, 1.0])
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, _ = adamw_update(params, g, state, lr=0.05,
                                        weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_clipping():
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    g = {"w": jnp.full((4,), 1e6)}
    _, _, m = adamw_update(params, g, state, lr=1e-3, clip_norm=1.0)
    assert float(m["grad_norm"]) > 1e5          # reported pre-clip norm
    # post-clip step size bounded by lr * (1 + wd)
    p2, _, _ = adamw_update(params, g, state, lr=1e-3, clip_norm=1.0)
    assert float(jnp.abs(p2["w"]).max()) < 1e-2


def test_adamw_bf16_moments():
    params = {"w": jnp.ones((8, 8))}
    state = adamw_init(params, moment_dtype=jnp.bfloat16)
    assert state.mu["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((8, 8))}
    p2, s2, _ = adamw_update(params, g, state, lr=1e-2)
    assert s2.mu["w"].dtype == jnp.bfloat16
    assert bool(jnp.isfinite(p2["w"]).all())


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.asarray(s), peak=1.0, warmup_steps=10,
                                 total_steps=100)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6, "warmup ascends"
    assert abs(max(lrs) - 1.0) < 0.05
    assert lrs[-1] < 0.2, "decays"
    assert lrs[-1] >= 0.1 * 0.95, "floor respected"


# ---------------------------------------------------------------------------
def test_synthetic_stream_determinism():
    s = SyntheticLMStream(vocab=256, seed=7)
    a = s.batch(step=12, batch_size=4, seq_len=16)
    b = s.batch(step=12, batch_size=4, seq_len=16)
    np.testing.assert_array_equal(a, b)
    c = s.batch(step=13, batch_size=4, seq_len=16)
    assert not np.array_equal(a, c)
    d = s.batch(step=12, batch_size=4, seq_len=16, shard=1, n_shards=2)
    assert not np.array_equal(a, d), "shards differ"


def test_synthetic_stream_learnable_structure():
    s = SyntheticLMStream(vocab=64, seed=0, noise=0.0)
    b = s.batch(0, 8, 32)
    perm = s._perm()
    assert np.array_equal(perm[b[:, :-1]], b[:, 1:]), "bigram structure"


def test_memmap_reader(tmp_path):
    toks = np.arange(10_000, dtype=np.uint16) % 251
    f = tmp_path / "tokens.bin"
    toks.tofile(f)
    r = MemmapTokenReader(f)
    a = r.batch(0, 4, 32)
    b = r.batch(0, 4, 32)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (4, 33)
    assert not np.array_equal(a, r.batch(1, 4, 32))


# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)}}
    mgr.save(5, tree, blocking=True)
    step, back = mgr.restore(tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    assert back["nested"]["b"].dtype == jnp.int32


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=True)
    steps = [s for s, _ in mgr._step_dirs()]
    assert steps == [3, 4], "keep=2 retains newest two"
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = {"x": jnp.ones((128, 128))}
    mgr.save(1, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_atomicity_no_partial(tmp_path):
    """tmp dirs never count as checkpoints."""
    mgr = CheckpointManager(tmp_path)
    (tmp_path / "tmp.9").mkdir()
    assert mgr.latest_step() is None


def test_train_restart_determinism(tmp_path):
    """Crash/restore reproduces the uninterrupted run exactly: train 6
    steps vs train 3 + restart + 3 — identical final parameters."""
    from repro.configs import ARCHS
    from repro.launch.train import train

    cfg = ARCHS["qwen2.5-14b"].reduced()
    kw = dict(batch=2, seq=16, peak_lr=1e-3)

    s_full, _ = train(cfg, steps=6, ckpt_dir=None, **kw)
    d1 = tmp_path / "ck"
    train(cfg, steps=3, ckpt_dir=str(d1), ckpt_every=3, **kw)
    s_resumed, _ = train(cfg, steps=6, ckpt_dir=str(d1), ckpt_every=3, **kw)

    for a, b in zip(jax.tree.leaves(s_full.params),
                    jax.tree.leaves(s_resumed.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)
