"""The shared wall-clock helper (DESIGN.md §11.1, kernels/timing.py):
median estimator, warmup discipline, injectable timer/sync, and the
benchmarks re-export staying the same object."""
import pytest

from repro.kernels import timing


# ---------------------------------------------------------------------------
# median
# ---------------------------------------------------------------------------

def test_median_odd_and_even():
    assert timing.median([3.0, 1.0, 2.0]) == 2.0
    # even length: the *upper* median — conservative for one-sided noise
    assert timing.median([4.0, 1.0, 2.0, 3.0]) == 3.0
    assert timing.median([5.0]) == 5.0


def test_median_empty_raises():
    with pytest.raises(ValueError):
        timing.median([])


def test_median_does_not_mutate_input():
    xs = [3.0, 1.0, 2.0]
    timing.median(xs)
    assert xs == [3.0, 1.0, 2.0]


# ---------------------------------------------------------------------------
# measure: a fake monotonic clock scripted per call makes the estimator
# deterministic — intervals are whatever the script says they are.
# ---------------------------------------------------------------------------

class _Clock:
    """timer() returns scripted instants; one tick per call."""

    def __init__(self, instants):
        self.instants = list(instants)

    def __call__(self):
        return self.instants.pop(0)


def test_measure_returns_median_interval():
    calls = []

    def fn():
        calls.append(1)
        return "result"

    synced = []
    # 3 timed reps -> 6 timer() calls; intervals 1.0, 5.0, 2.0 -> median 2.0
    clock = _Clock([0.0, 1.0, 10.0, 15.0, 20.0, 22.0])
    t = timing.measure(fn, reps=3, warmup=2, timer=clock,
                       sync=synced.append)
    assert t == 2.0
    assert len(calls) == 5              # 2 warmup + 3 timed
    assert synced == ["result"] * 5     # every call synced, warmups too


def test_measure_warmup_outside_timed_region():
    # warmup calls must not consume timer ticks: the clock only has
    # exactly enough instants for the timed reps.
    clock = _Clock([0.0, 3.0])
    t = timing.measure(lambda: None, reps=1, warmup=4, timer=clock,
                       sync=lambda x: x)
    assert t == 3.0
    assert clock.instants == []


def test_measure_passes_args_through():
    seen = []
    clock = _Clock([0.0, 1.0])
    timing.measure(lambda a, b: seen.append((a, b)), "x", 7,
                   reps=1, warmup=0, timer=clock, sync=lambda x: x)
    assert seen == [("x", 7)]


def test_measure_validates_reps_and_warmup():
    with pytest.raises(ValueError):
        timing.measure(lambda: None, reps=0)
    with pytest.raises(ValueError):
        timing.measure(lambda: None, warmup=-1)


def test_measure_default_sync_blocks_jax_values():
    import jax.numpy as jnp

    # the lazy jax.block_until_ready default: just exercise the real path
    t = timing.measure(lambda: jnp.arange(4) + 1, reps=1, warmup=1)
    assert t >= 0.0


def test_benchmarks_reexport_is_the_same_object():
    from benchmarks import timing as bench_timing

    assert bench_timing.measure is timing.measure
    assert bench_timing.median is timing.median
